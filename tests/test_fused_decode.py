"""Fused single-pass pruned-decode engine: parity against the composed
three-pass oracle (approx_score → top-k → gather) and against dense
attention, across bf16 and int8 cache modes, at three levels:

  kernel  — Pallas (interpret) vs the pure-jnp oracle in kernels/ref.py
  engine  — decode_attention(fused=True) vs the composed path, including
            the charge-domain accumulated-score table across evictions
  model   — scanned generation through a full transformer
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig
from repro.core.attention import decode_attention
from repro.core.cache import init_cache
from repro.kernels import ref
from repro.kernels.fused_decode import fused_decode

jax.config.update("jax_platform_name", "cpu")


def _kernel_args(bh, g, d, dv, s, key=0, quantized=False, valid_frac=0.8,
                 prot_frac=0.1):
    ks = jax.random.split(jax.random.PRNGKey(key), 10)
    q = jax.random.normal(ks[0], (bh, g, d))
    qq = jax.random.randint(ks[1], (bh, g, d), -7, 8, jnp.int8)
    qs = jax.random.uniform(ks[2], (bh, g)) + 0.05
    mirror = jax.random.randint(ks[3], (bh, s, d), -7, 8, jnp.int8)
    ms = jax.random.uniform(ks[4], (bh, s)) + 0.05
    if quantized:
        k = jax.random.randint(ks[5], (bh, s, d), -127, 128, jnp.int8)
        v = jax.random.randint(ks[6], (bh, s, dv), -127, 128, jnp.int8)
        kscale = jax.random.uniform(ks[7], (bh, s)) * 0.02 + 0.001
        vscale = jax.random.uniform(ks[8], (bh, s)) * 0.02 + 0.001
    else:
        k = jax.random.normal(ks[5], (bh, s, d))
        v = jax.random.normal(ks[6], (bh, s, dv))
        kscale = jnp.ones((bh, s))
        vscale = jnp.ones((bh, s))
    valid = jax.random.bernoulli(ks[9], valid_frac, (bh, s)).astype(jnp.int8)
    prot = (jax.random.bernoulli(jax.random.PRNGKey(key + 77), prot_frac,
                                 (bh, s)).astype(jnp.int8)) * valid
    return q, qq, qs, mirror, ms, kscale, vscale, valid, prot, k, v


@pytest.mark.parametrize("bh,g,d,dv,s,nb,sk,quantized", [
    (2, 4, 32, 32, 64, 1, 16, False),
    (2, 4, 32, 32, 64, 2, 16, False),     # block-local race
    (3, 2, 16, 24, 48, 4, 8, True),       # int8 K/V, dv != d
    (1, 1, 16, 16, 40, 1, 8, False),      # single head, ragged S
    (2, 8, 32, 32, 96, 3, 12, True),
])
def test_fused_kernel_matches_ref(bh, g, d, dv, s, nb, sk, quantized):
    args = _kernel_args(bh, g, d, dv, s, key=s + nb, quantized=quantized)
    out_k, probs_k = fused_decode(*args, select_k=sk, num_blocks=nb,
                                  interpret=True)
    out_r, probs_r = ref.fused_decode_ref(*args, select_k=sk, num_blocks=nb)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs_k), np.asarray(probs_r),
                               atol=1e-6)


@pytest.mark.parametrize("nb,align", [(1, 128), (2, 16), (4, 128)])
def test_fused_kernel_block_alignment_preserves_partition(nb, align):
    """TPU lane alignment pads each selection block IN PLACE (bs0 → bs),
    so block boundaries — and therefore the block-local race and its
    winners — must be identical to the unaligned oracle partition."""
    bh, g, d, dv, s, sk = 2, 2, 16, 16, 64, 8
    args = _kernel_args(bh, g, d, dv, s, key=5)
    out_a, probs_a = fused_decode(*args, select_k=sk, num_blocks=nb,
                                  interpret=True, block_align=align)
    out_r, probs_r = ref.fused_decode_ref(*args, select_k=sk, num_blocks=nb)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs_a), np.asarray(probs_r),
                               atol=1e-6)


def test_fused_kernel_protected_always_selected():
    """Slots flagged protected must win the race even with the worst
    scores: give one protected slot a huge NEGATIVE mirror score and check
    it still contributes to the output (its V row is gathered)."""
    bh, g, d, dv, s, sk = 1, 2, 16, 16, 32, 4
    args = list(_kernel_args(bh, g, d, dv, s, key=3, valid_frac=1.0,
                             prot_frac=0.0))
    q, qq, qs, mirror, ms, kscale, vscale, valid, prot, k, v = args
    prot = prot.at[0, 7].set(1)
    ms = ms.at[0, 7].set(1e4)              # terrible (dominant) raw score…
    mirror = mirror.at[0, 7].set(-7)       # …uniformly negative
    v = v.at[0, 7].set(100.0)              # detectable payload
    out, _ = fused_decode(q, qq, qs, mirror, ms, kscale, vscale, valid,
                          prot, k, v, select_k=sk, num_blocks=1,
                          interpret=True)
    out_ref, _ = ref.fused_decode_ref(q, qq, qs, mirror, ms, kscale,
                                      vscale, valid, prot, k, v,
                                      select_k=sk, num_blocks=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-4)
    # the protected slot's exact logit is fine (real K), so its 100-valued
    # V row must show up in the attention mix
    assert np.asarray(out).max() > 1.0


def _run_steps(prune, steps=40, B=2, HK=2, HQ=4, D=16, seed=0):
    cache = init_cache(B, HK, D, prune.slots, prune, jnp.float32)
    fn = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))
    outs = []
    for i in range(steps):
        ks = jax.random.split(jax.random.PRNGKey(seed * 1000 + i), 3)
        q = jax.random.normal(ks[0], (B, HQ, D))
        kn = jax.random.normal(ks[1], (B, HK, D))
        vn = jax.random.normal(ks[2], (B, HK, D))
        cache, o = fn(cache, q, kn, vn)
        outs.append(np.asarray(o))
    return np.stack(outs), cache


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("nb", [1, 2])
def test_fused_engine_matches_composed(kv_dtype, nb):
    """40 decode steps (spanning evictions): fused out + accumulated-score
    table must track the composed three-pass path."""
    base = PruneConfig(policy="unicaim", heavy_budget=24, reserve=8,
                       sink_tokens=2, recent_window=4, select_k=8,
                       select_blocks=nb, score_bits=3, query_bits=4,
                       kv_dtype=kv_dtype)
    o_comp, c_comp = _run_steps(base)
    o_fused, c_fused = _run_steps(
        dataclasses.replace(base, fused=True, fused_backend="xla"))
    np.testing.assert_allclose(o_fused, o_comp, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_fused.acc),
                               np.asarray(c_comp.acc), atol=1e-5)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_fused_pallas_engine_matches_composed(kv_dtype):
    """Same parity through the Pallas kernel (interpret mode on CPU)."""
    base = PruneConfig(policy="unicaim", heavy_budget=24, reserve=8,
                       sink_tokens=2, recent_window=4, select_k=8,
                       score_bits=3, query_bits=4, kv_dtype=kv_dtype)
    o_comp, c_comp = _run_steps(base, steps=20)
    o_pall, c_pall = _run_steps(
        dataclasses.replace(base, fused=True, fused_backend="pallas"),
        steps=20)
    np.testing.assert_allclose(o_pall, o_comp, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_pall.acc),
                               np.asarray(c_comp.acc), atol=1e-5)


def test_fused_matches_dense_when_selection_covers_cache():
    """With select_k == slots every (valid) token is a winner, so the fused
    engine must reproduce dense attention on the same cache contents —
    the 'protected tokens see exact attention' guarantee end to end."""
    slots = 32
    dense = PruneConfig(policy="dense", heavy_budget=slots, reserve=0,
                        sink_tokens=0, recent_window=1, select_k=1)
    fused = PruneConfig(policy="unicaim", heavy_budget=slots - 8, reserve=8,
                        sink_tokens=2, recent_window=4, select_k=slots,
                        score_bits=8, query_bits=8, fused=True,
                        fused_backend="xla")
    # stay below `slots` steps: both policies append-only → same contents
    o_dense, _ = _run_steps(dense, steps=slots - 4)
    o_fused, _ = _run_steps(fused, steps=slots - 4)
    np.testing.assert_allclose(o_fused, o_dense, atol=1e-4)


def test_fused_model_scan_generation_matches_loop():
    """Full transformer with the fused engine: the scanned serving decode
    must emit exactly the per-token Python loop's tokens."""
    from repro.configs.base import get_config, reduced
    from repro.core import baselines
    from repro.launch.serve import generate_scan, greedy_generate
    from repro.models.transformer import Model

    cfg = reduced(get_config("longchat-7b"))
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8, fused=True)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, cfg.vocab_size)}
    t1, _ = greedy_generate(model, params, batch, steps=8)
    t2, _ = jax.jit(lambda p, b: generate_scan(model, p, b, 8))(params,
                                                                batch)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
