"""Radix-trie prefix cache + prefix-sharing admission tests.

Covers the trie itself (longest-prefix match, edge splitting, LRU
byte-budget eviction), the slot-alignment gate that decides whether a
finalized (pruned) cache may donate raw prefix rows, the model-level
bitwise guarantee — resuming a chunked prefill from cached workspace
rows reproduces the from-scratch whole-prompt prefill bit-for-bit, for
bf16 AND int8 caches — and the ServeLoop integration end to end
(Request API, exact-state hits, suffix-resume hits, lane isolation,
deprecation of the positional/legacy surface).
"""
import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.prefix_cache import PrefixCache, RowsEntry, StateEntry
from repro.launch.serve import (Request, RequestHandle, SamplingParams,
                                ServeLoop)
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8)


@pytest.fixture(scope="module")
def setup():
    # attn_chunk == 16 matches the chunk_prefill grid used throughout, so
    # whole-bucket and chunked prefills share one accumulation order
    cfg = dataclasses.replace(reduced(get_config("granite-3-2b")),
                              attn_chunk=16)
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


def _rows(depth, seed=0, nbytes=None):
    rng = np.random.default_rng(seed)
    e = RowsEntry(depth, rng.standard_normal((2, 2, depth, 4)),
                  rng.standard_normal((2, 2, depth, 4)),
                  rng.standard_normal((2, 2, depth)))
    if nbytes is not None:
        e.nbytes = nbytes
    return e


# -- trie ---------------------------------------------------------------------


def test_trie_longest_prefix_match():
    pc = PrefixCache(1 << 30)
    toks = list(range(100, 164))                     # 64 distinct tokens
    pc.insert_rows(toks[:16], _rows(16))
    pc.insert_rows(toks[:48], _rows(48))
    # deepest boundary within the cap wins
    assert pc.match_rows(toks, cap=64).depth == 48
    assert pc.match_rows(toks, cap=32).depth == 16
    assert pc.match_rows(toks, cap=8) is None
    # a diverging suffix only matches the shared part
    fork = toks[:32] + [7] * 32
    assert pc.match_rows(fork, cap=64).depth == 16
    # match_state is exact-only
    pc.insert_state(toks, StateEntry(64, 64, np.zeros(8), {"x": np.zeros(4)}))
    assert pc.match_state(toks).length == 64
    assert pc.match_state(toks[:48]) is None
    assert pc.match_state(toks + [1]) is None


def test_trie_edge_split_preserves_entries():
    """Inserting a diverging key splits a compressed edge without losing
    the entry that lived past the split point."""
    pc = PrefixCache(1 << 30)
    a = [1, 2, 3, 4, 5, 6]
    b = [1, 2, 3, 9, 9, 9]
    pc.insert_rows(a, _rows(6))
    pc.insert_rows(b, _rows(6, seed=1))
    assert pc.match_rows(a, cap=6).depth == 6
    assert pc.match_rows(b, cap=6).depth == 6
    assert pc.match_rows([1, 2, 3, 4], cap=6) is None
    assert pc.entries == 2


def test_trie_lru_eviction_under_byte_budget():
    one = _rows(4, nbytes=100).nbytes               # pin entry size
    pc = PrefixCache(250)                           # room for two
    pc.insert_rows([1], _rows(1, nbytes=100))
    pc.insert_rows([2], _rows(1, seed=1, nbytes=100))
    assert pc.entries == 2 and pc.evictions == 0
    # touching [1] makes [2] the LRU victim of the next insert
    assert pc.match_rows([1, 5], cap=1).depth == 1
    pc.insert_rows([3], _rows(1, seed=2, nbytes=100))
    assert pc.entries == 2 and pc.evictions == 1
    assert pc.match_rows([2, 5], cap=1) is None     # evicted
    assert pc.match_rows([1, 5], cap=1) is not None
    assert pc.match_rows([3, 5], cap=1) is not None
    assert pc.bytes == 200
    assert one == 100


def test_trie_oversized_and_disabled_inserts_refused():
    pc = PrefixCache(50)
    assert not pc.insert_rows([1, 2], _rows(2, nbytes=100))  # > budget
    assert pc.entries == 0 and pc.bytes == 0
    off = PrefixCache(0)
    assert not off.insert_rows([1], _rows(1))
    assert off.match_rows([1], cap=1) is None


# -- slot-alignment gate ------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_slot_alignment_rejects_pruned_and_quantized(setup, kv_dtype):
    """`cache_prefix_rows` only accepts a finalized cache whose slots are
    the raw identity-ordered prefix: a prefill short enough that static
    pruning kept everything (and full precision) passes; a pruned layout
    (prompt > heavy budget ⇒ top-k rewrote the slots) and any int8
    mirror are refused — their rows are not the raw prefix."""
    from repro.surgery import cache_prefix_rows, prefix_slot_aligned
    cfg, _, params = setup
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    short = _prompt(cfg, 16, seed=1)
    _, st = jax.jit(model.prefill_one)(params, jnp.asarray(short),
                                       jnp.asarray(16, jnp.int32))
    if kv_dtype == "int8":
        assert not prefix_slot_aligned(st.kv, 16)
        assert cache_prefix_rows(st.kv, 16) is None
        return
    assert prefix_slot_aligned(st.kv, 16)
    k, v, acc = cache_prefix_rows(st.kv, 16)
    assert k.shape[-2] == 16 and acc.shape[-1] == 16
    long = _prompt(cfg, 64, seed=2)                 # > heavy=48 ⇒ pruned
    padded = np.zeros(64, long.dtype)
    padded[:64] = long
    _, st2 = jax.jit(model.prefill_one)(params, jnp.asarray(padded),
                                        jnp.asarray(64, jnp.int32))
    assert not prefix_slot_aligned(st2.kv, 64)
    assert cache_prefix_rows(st2.kv, 64) is None


# -- model-level bitwise resume ----------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_resume_from_cached_rows_bitwise(setup, kv_dtype):
    """The tentpole invariant: workspace rows snapped at a chunk boundary
    of prompt A, resumed with prompt B's suffix chunks, reproduce B's
    from-scratch prefill BIT-FOR-BIT — logits and every cache leaf, for
    bf16 and int8 alike (the snapshot predates pruning/quantization)."""
    cfg, _, params = setup
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    t, bucket, C = 64, 64, 16
    shared = _prompt(cfg, 48, seed=3)
    a = np.concatenate([shared, _prompt(cfg, 16, seed=4)])
    b = np.concatenate([shared, _prompt(cfg, 16, seed=5)])
    chunk = jax.jit(model.prefill_chunk)
    fin = jax.jit(model.prefill_finalize)
    length = jnp.asarray([t])

    def run_chunks(ps, toks, lo, hi, x_last=None):
        for ci in range(lo, hi):
            x_last, ps = chunk(params, ps,
                               jnp.asarray(toks[None, ci * C:(ci + 1) * C]),
                               jnp.asarray(ci * C, jnp.int32), length)
        return x_last, ps

    # prefill A from scratch, snapping the boundary-48 workspace prefix
    ps = model.init_prefill_chunk_state(1, bucket)
    _, ps = run_chunks(ps, a, 0, 3)
    snap = RowsEntry(48, np.asarray(ps.k[:, 0, :, :48]),
                     np.asarray(ps.v[:, 0, :, :48]),
                     np.asarray(ps.acc[:, 0, :, :48]))
    # resume B's final chunk on the snapshot vs B fully from scratch
    ps_r = model.resume_prefill_chunk_state(snap.k, snap.v, snap.acc, bucket)
    x_r, ps_r = run_chunks(ps_r, b, 3, 4)
    lg_r, st_r = fin(params, ps_r, x_r, jnp.asarray(48, jnp.int32), length)
    ps_f = model.init_prefill_chunk_state(1, bucket)
    x_f, ps_f = run_chunks(ps_f, b, 0, 4)
    lg_f, st_f = fin(params, ps_f, x_f, jnp.asarray(48, jnp.int32), length)
    np.testing.assert_array_equal(np.asarray(lg_r), np.asarray(lg_f))
    for x, y in zip(jax.tree.leaves(st_r), jax.tree.leaves(st_f)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the chunked path itself is bitwise vs the whole-prompt prefill
    # (C == attn_chunk), so transitively resume == whole-prompt
    lg_w, st_w = jax.jit(model.prefill_one)(params, jnp.asarray(b),
                                            jnp.asarray(t, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_r[0]), np.asarray(lg_w))
    for x, y in zip(jax.tree.leaves(st_r), jax.tree.leaves(st_w)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- engine integration -------------------------------------------------------


def _loop(model, params, **kw):
    kw.setdefault("lanes", 2)
    kw.setdefault("max_new", 8)
    kw.setdefault("block", 4)
    kw.setdefault("chunk_prefill", 16)
    return ServeLoop(model, params, **kw)


def _shared_prompts(cfg, n=4, shared=48, suffix=16, seed=0):
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, shared)
    return [np.concatenate([head, rng.integers(0, cfg.vocab_size, suffix)])
            for _ in range(n)]


def test_serve_prefix_reuse_matches_cold_loop(setup):
    """Shared-prefix admission through the cache: fewer chunk dispatches,
    hit/dedup counters populated, and every token stream identical to a
    cache-less twin loop."""
    cfg, model, params = setup
    prompts = _shared_prompts(cfg)
    warm = _loop(model, params, prefix_cache_bytes=64 << 20)
    cold = _loop(model, params)
    hw = [warm.submit(Request(prompt=p)) for p in prompts]
    hc = [cold.submit(Request(prompt=p)) for p in prompts]
    warm.run()
    cold.run()
    for a, b in zip(hw, hc):
        assert a.done and b.done
        assert a.tokens == b.tokens
    assert warm.counters["chunk_dispatches"] < cold.counters["chunk_dispatches"]
    agg = warm.aggregate()
    assert agg["prefix_hit_rate"] == pytest.approx(0.75)   # 3 of 4 hit
    assert agg["prefix_dedup_ratio"] > 0.5                 # 144/256 reused
    assert warm.counters["prefix_copies"] == 3
    assert warm.counters["prefix_tokens_reused"] == 144
    hit_stats = [h.stats for h in hw[1:]]
    assert all(s.prefix_tokens == 48 for s in hit_stats)
    assert all(not s.prefix_exact for s in hit_stats)
    assert hw[0].stats.prefix_tokens == 0


def test_serve_exact_hit_skips_prefill_entirely(setup):
    cfg, model, params = setup
    loop = _loop(model, params, max_new=4, prefix_cache_bytes=64 << 20)
    prompt = _shared_prompts(cfg, n=1)[0]
    h1 = loop.submit(Request(prompt=prompt, max_new=4))
    loop.run()
    before = (loop.counters["prefill_dispatches"],
              loop.counters["chunk_dispatches"])
    h2 = loop.submit(Request(prompt=prompt, max_new=4))
    loop.run()
    after = (loop.counters["prefill_dispatches"],
             loop.counters["chunk_dispatches"])
    assert before == after                         # zero prefill work
    assert loop.counters["prefix_exact_hits"] == 1
    assert h2.stats.prefix_exact and h2.stats.prefill_chunks == 0
    assert h1.tokens == h2.tokens


def test_serve_prefix_copy_does_not_alias_lane_state(setup):
    """Lane isolation: decoding on a lane admitted from a cached prefix
    must not mutate the cached donor — later hits see the same bytes."""
    cfg, model, params = setup
    prompts = _shared_prompts(cfg)
    loop = _loop(model, params, prefix_cache_bytes=64 << 20)
    loop.submit(Request(prompt=prompts[0]))
    loop.run()
    entry = loop.prefix_cache.match_rows(prompts[1], cap=48)
    saved = (entry.k.copy(), entry.v.copy(), entry.acc.copy())
    for p in prompts[1:]:
        loop.submit(Request(prompt=p))
    loop.run()
    assert entry is loop.prefix_cache.match_rows(prompts[1], cap=48)
    for got, want in zip((entry.k, entry.v, entry.acc), saved):
        np.testing.assert_array_equal(got, want)


def test_serve_reuse_prefix_opt_out(setup):
    cfg, model, params = setup
    prompts = _shared_prompts(cfg, n=2)
    loop = _loop(model, params, prefix_cache_bytes=64 << 20)
    for p in prompts:
        loop.submit(Request(prompt=p, reuse_prefix=False))
    loop.run()
    assert loop.counters["prefix_lookups"] == 0
    assert loop.counters["prefix_hits"] == 0
    assert loop.prefix_cache.entries == 0          # nothing inserted either


def test_serve_whole_bucket_donor_feeds_chunked_resume(setup):
    """A short prompt admitted whole-bucket (bucket <= C) whose layout
    stayed slot-aligned becomes a rows donor for a longer chunked
    admission sharing it as a prefix."""
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    head = rng.integers(0, cfg.vocab_size, 16)     # <= heavy ⇒ unpruned
    long = np.concatenate([head, rng.integers(0, cfg.vocab_size, 48)])
    loop = _loop(model, params, prefix_cache_bytes=64 << 20)
    loop.submit(Request(prompt=head, max_new=2))
    loop.run()
    assert loop.prefix_cache.match_rows(long, cap=48) is not None
    h = loop.submit(Request(prompt=long, max_new=2))
    loop.run()
    assert h.stats.prefix_tokens == 16
    cold = _loop(model, params)
    h2 = cold.submit(Request(prompt=long, max_new=2))
    cold.run()
    assert h.tokens == h2.tokens


# -- Request API + deprecations ----------------------------------------------


def test_request_api_surface(setup):
    cfg, model, params = setup
    loop = _loop(model, params)
    h = loop.submit(Request(prompt=_prompt(cfg, 20), max_new=3))
    assert isinstance(h, RequestHandle) and not h.done
    with pytest.raises(TypeError):                 # mixing old+new forms
        loop.submit(Request(prompt=_prompt(cfg, 8)), max_new=4)
    req = Request(prompt=_prompt(cfg, 8), max_new=2)
    loop.submit(req)
    with pytest.raises(ValueError):                # double submission
        loop.submit(req)
    with pytest.raises(TypeError):                 # positional construction
        Request(_prompt(cfg, 8))
    loop.run()
    assert h.done and len(h.tokens) == 3


def test_per_request_sampling_seed_is_deterministic(setup):
    """Same prompt + same `sample_seed` ⇒ the same sampled first token,
    independent of loop-stream history; overrides force solo admission."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 12, seed=11)
    sp = SamplingParams(temperature=0.8, top_k=5)
    loop = _loop(model, params, lanes=2, max_new=1)
    hs = [loop.submit(Request(prompt=prompt, max_new=1, sampling=sp,
                              sample_seed=123)) for _ in range(2)]
    loop.run()
    assert hs[0].tokens == hs[1].tokens and len(hs[0].tokens) == 1
    assert all(h.stats.group_size == 1 for h in hs)


def test_legacy_surface_warns(setup):
    cfg, model, params = setup
    loop = _loop(model, params)
    with pytest.warns(DeprecationWarning):
        rid = loop.submit(_prompt(cfg, 12), 2, 0.0)
    assert isinstance(rid, int)
    loop.run()
    with pytest.warns(DeprecationWarning):
        loop.admit(np.stack([_prompt(cfg, 16, seed=i) for i in range(2)]))
    with pytest.warns(DeprecationWarning):
        loop.step()
    with pytest.warns(DeprecationWarning):
        loop.step_block()


# -- preemption-aware caching -------------------------------------------------


def test_preempted_lane_feeds_prefix_cache(setup):
    """A preempted lane's captured state donates its prefix rows to the
    trie through the same slot-alignment gate as finalization — but only
    when the capture is not decode-advanced (fill == step == prompt
    length): an un-decoded victim donates, a mid-decode victim is
    refused by the gate."""
    cfg, model, params = setup
    loop = _loop(model, params, lanes=1, prefix_cache_bytes=64 << 20)
    p = _prompt(cfg, 32, 5)
    h_v = loop.submit(Request(prompt=p, max_new=8, priority=0))
    for _ in range(8):                         # drive the chunked prefill
        loop.schedule()
        loop._advance_chunked()
        if loop.active.any():
            break
    assert loop.active.any()
    loop.submit(Request(prompt=_prompt(cfg, 16, 6), max_new=4, priority=5))
    loop.schedule()                            # evicts the un-decoded victim
    assert loop.counters["preemptions"] == 1
    assert loop.counters["preempt_cache_inserts"] == 1

    # a sibling sharing the 32-token prefix resumes from the donated rows
    sib = np.concatenate([p, _prompt(cfg, 16, 7)])
    h_s = loop.submit(Request(prompt=sib, max_new=4))
    loop.run()
    assert h_s.stats.prefix_tokens == 32
    assert loop.counters["prefix_copies"] >= 1
    cold = _loop(model, params, lanes=1)
    h_c = cold.submit(Request(prompt=sib, max_new=4))
    cold.run()
    assert h_s.tokens == h_c.tokens            # donated rows are bitwise
    assert h_v.tokens == _solo_tokens(model, params,
                                      dict(prompt=p, max_new=8))

    # round 2: a victim that already decoded a block is refused
    h2 = loop.submit(Request(prompt=_prompt(cfg, 32, 8), max_new=8,
                             priority=0))
    for _ in range(8):
        loop.schedule()
        loop._advance_chunked()
        if loop.active.any():
            break
    loop._step_block()
    loop.submit(Request(prompt=_prompt(cfg, 16, 9), max_new=4, priority=5))
    loop.schedule()
    assert loop.counters["preemptions"] == 2
    assert loop.counters["preempt_cache_inserts"] == 1   # gate refused
    loop.run()
    assert h2.done


def _solo_tokens(model, params, req_kw):
    loop = _loop(model, params, lanes=1)
    h = loop.submit(Request(**req_kw))
    loop.run()
    return h.tokens


# -- surgery namespace --------------------------------------------------------


def test_surgery_namespace_reexports():
    import repro.surgery as surgery
    from repro.core import cache as kvcache
    from repro.models import transformer as T
    for name in surgery.__all__:
        assert getattr(surgery, name) is not None
    assert surgery.state_lane_insert is T.lane_insert
    assert surgery.state_lanes_insert is T.lanes_insert
    assert surgery.state_lane_select is T.lane_select
    assert surgery.kv_lane_insert is kvcache.lane_insert
    assert surgery.slot_window is kvcache.slot_window
    assert surgery.cache_prefix_rows is kvcache.cache_prefix_rows
