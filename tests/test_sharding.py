"""Sharding rules: divisibility fallbacks, combined axes, cache specs, and
a tiny-mesh pjit end-to-end check (runs on however many host devices exist)."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import PruneConfig, get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model
from repro.runtime.sharding import (decode_state_pspecs, logical_to_spec,
                                    params_pspecs, use_mesh)

jax.config.update("jax_platform_name", "cpu")


def _fake_mesh(shape, axes):
    """Abstract mesh over fake devices for spec computation only."""
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:  # older jax: single tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


MESH = _fake_mesh((16, 16), ("data", "model"))
MESH3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisibility_fallback_replicates():
    with use_mesh(MESH):
        # 24 heads don't divide 16 → replicated
        assert logical_to_spec(("heads",), (24,)) == P()
        assert logical_to_spec(("heads",), (32,)) == P("model")


def test_combined_axes_batch():
    with use_mesh(MESH3):
        spec = logical_to_spec(("batch", None), (256, 10))
        assert spec == P(("pod", "data"))
    with use_mesh(MESH):
        assert logical_to_spec(("batch", None), (256, 10)) == P("data")


def test_param_rules_attention_and_moe():
    cfg = reduced(get_config("grok-1-314b"),
                  d_model=64, n_heads=16, n_kv_heads=16, head_dim=16)
    model = Model(cfg, baselines.unicaim(48, 16, 16))
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    with use_mesh(_fake_mesh((2, 2), ("data", "model"))):
        specs = params_pspecs(shapes)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_name = {"/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path): s for path, s in flat}
    wq = [v for k, v in by_name.items() if k.endswith("attn/wq")][0]
    assert wq == P(None, "data", "model")        # stack, fsdp, qdim
    wi = [v for k, v in by_name.items() if "moe/wi" in k][0]
    # [stack, experts→model, d→fsdp(data), ff replicated]
    assert wi[1] == "model" and wi[2] == "data"
    router = [v for k, v in by_name.items() if "moe/router" in k][0]
    assert router == P()                         # replicated


def test_decode_state_specs_kv_heads_vs_slots():
    prune = PruneConfig(policy="unicaim", heavy_budget=1984, reserve=64,
                        select_k=64)
    with use_mesh(MESH):
        # kv_heads=32 divides 16 → heads sharded, slots unsharded
        cfg = reduced(get_config("zamba2-7b"), n_kv_heads=32, n_heads=32,
                      num_layers=12, attn_period=6)
        m = Model(cfg, prune, decode_slots=2048)
        st = jax.eval_shape(lambda: m.init_decode_state(16))
        specs = decode_state_pspecs(st)
        assert specs.kv.k[2] == "model"
        # kv_heads=2 → slots take the model axis
        cfg2 = reduced(get_config("starcoder2-3b"), n_kv_heads=2)
        m2 = Model(cfg2, prune, decode_slots=2048)
        st2 = jax.eval_shape(lambda: m2.init_decode_state(16))
        specs2 = decode_state_pspecs(st2)
        assert specs2.kv.k[2] is None
        assert specs2.kv.k[3] == "model"


def test_decode_state_specs_long_context_combines_axes():
    prune = PruneConfig(policy="unicaim", heavy_budget=524224, reserve=64,
                        select_k=2048)
    with use_mesh(MESH):
        cfg = reduced(get_config("llava-next-mistral-7b"), n_kv_heads=2)
        m = Model(cfg, prune, decode_slots=524288)
        st = jax.eval_shape(lambda: m.init_decode_state(1))  # batch 1
        specs = decode_state_pspecs(st)
        # batch can't shard; slots fold model AND the idle data axis
        assert specs.kv.k[3] == ("model", "data")


def test_pjit_end_to_end_tiny_mesh():
    """Real pjit run on the host's devices (1 on CI — still exercises the
    NamedSharding path)."""
    n = len(jax.devices())
    mesh = jax.make_mesh((1, n), ("data", "model"))
    cfg = reduced(get_config("granite-3-2b"), n_heads=4, n_kv_heads=2)
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        shardings = jax.tree.map(
            lambda s: jax.NamedSharding(mesh, s), params_pspecs(params),
            is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, shardings)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 32), 0, cfg.vocab_size)}
        logits, aux = jax.jit(model.train_logits)(params, batch)
        assert not np.isnan(np.asarray(logits)).any()
