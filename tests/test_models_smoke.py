"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step + prefill/decode on CPU; asserts shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs, reduced
from repro.core import baselines
from repro.launch.train import init_train_state, make_train_step
from repro.models.transformer import Model
from repro.optim import adamw

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = [
    "whisper-base", "minitron-8b", "starcoder2-3b", "phi3-medium-14b",
    "granite-3-2b", "deepseek-v3-671b", "grok-1-314b", "zamba2-7b",
    "mamba2-1.3b", "llava-next-mistral-7b", "longchat-7b",
]

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16, sink_tokens=2,
                          recent_window=8)


def _batch(cfg, B=2, T=64, seed=0):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, T), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_embed"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.frontend_len, cfg.d_model))
    elif cfg.frontend != "none":
        batch[f"{cfg.frontend}_embed"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.train_logits)(params, batch)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, PRUNE)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt_cfg, total_steps=10))
    state2, metrics = step(state, _batch(cfg))
    assert int(state2.opt.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, state2.params))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_roundtrip(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, state = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)
    for _ in range(4):
        logits, state = decode(params, state, tok)
        assert not np.isnan(np.asarray(logits)).any()
        tok = jnp.argmax(logits, -1)
    if state.kv is not None:
        # decode advanced the cache step counters
        assert (np.asarray(state.kv.step) >= 4).all()


def test_all_assigned_archs_registered():
    known = set(list_archs())
    for a in ALL_ARCHS:
        assert a in known


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "grok-1-314b",
                                  "minitron-8b", "zamba2-7b",
                                  "mamba2-1.3b", "phi3-medium-14b"])
def test_full_config_param_counts_sane(arch):
    """Analytic param counts land near the published sizes."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "deepseek-v3-671b": (600e9, 760e9),
        "grok-1-314b": (280e9, 360e9),
        "minitron-8b": (7e9, 10.5e9),
        "zamba2-7b": (6e9, 9e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "phi3-medium-14b": (12e9, 16e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B"
    if arch == "deepseek-v3-671b":
        a = cfg.active_param_count()
        assert 30e9 <= a <= 45e9, f"active {a/1e9:.1f}B"
