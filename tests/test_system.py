"""End-to-end behaviour tests for the UniCAIM system.

The paper's headline application claims, miniaturised to CPU scale:
  1. fixed-size cache enables unbounded-length decoding (memory never grows)
  2. quantized CAM scoring + top-k preserves generation vs dense
  3. needle retrieval: heavy tokens survive static pruning
  4. the serving loop + technique compose into a working system
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import ServeLoop
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")


def _model(arch="granite-3-2b", prune=None, **red):
    cfg = reduced(get_config(arch), **red)
    prune = prune or baselines.unicaim(heavy=48, reserve=16, select_k=16,
                                       sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_unbounded_decode_fixed_memory():
    """Decode 3× past the cache budget: state size is constant and outputs
    stay finite — the paper's fixed-size cache claim."""
    cfg, model, params = _model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 80), 0,
                              cfg.vocab_size)
    logits, state = jax.jit(model.prefill)(params, {"tokens": toks})
    decode = jax.jit(model.decode_step)
    size0 = sum(x.nbytes for x in jax.tree.leaves(state))
    tok = jnp.argmax(logits, -1)
    for i in range(3 * 64):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, -1)
        assert not np.isnan(np.asarray(logits)).any()
    assert sum(x.nbytes for x in jax.tree.leaves(state)) == size0
    assert int(state.kv.valid.sum(axis=-1).max()) <= 64  # slots bound


def test_generation_tracks_dense_reference():
    """Decode distributions stay close to the dense cache at a 80% budget,
    and closer than StreamingLLM at the same budget (Fig. 13 analog)."""
    cfg, model, params = _model()
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 80), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    dense = Model(cfg, baselines.dense(256))
    lg_d, st_d = jax.jit(dense.prefill)(params, batch)

    def drift(m):
        lg, st = jax.jit(m.prefill)(params, batch)
        d = jax.jit(m.decode_step)
        dd = jax.jit(dense.decode_step)
        tot, tok = 0.0, jnp.argmax(lg_d, -1)
        lgd, std = lg_d, st_d
        for _ in range(8):
            lg, st = d(params, st, tok)
            lgd, std = dd(params, std, tok)
            tot += float(jnp.mean(jnp.abs(jax.nn.softmax(lg)
                                          - jax.nn.softmax(lgd))))
            tok = jnp.argmax(lgd, -1)
        return tot

    d_uni = drift(model)
    assert d_uni < 0.02, d_uni                  # close to dense
    # (the UniCAIM-vs-StreamingLLM ordering needs a TRAINED model with
    #  peaked attention — covered by test_integration.test_policy_quality
    #  _ordering and benchmarks/bench_accuracy.py)


def test_needle_token_survives_static_pruning():
    """A token every head attends to strongly must be kept by the
    accumulated-score prefill pruning."""
    from repro.core.cache import init_cache, prefill_fill
    B, Hk, N, d = 1, 2, 128, 16
    prune = baselines.unicaim(heavy=24, reserve=8, select_k=8,
                              sink_tokens=2, recent_window=4)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, Hk, N, d))
    acc = jnp.zeros((B, Hk, N)).at[:, :, 77].set(50.0)  # the needle
    cache = init_cache(B, Hk, d, prune.slots, prune, jnp.float32)
    cache = prefill_fill(cache, k, k, acc, prune)
    kept = np.asarray(cache.pos[0])
    for h in range(Hk):
        assert 77 in kept[h].tolist()


def test_serve_loop_continuous_batching():
    cfg, model, params = _model()
    loop = ServeLoop(model, params, lanes=2, prompt_len=64, max_new=6)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 64))
    loop.admit(prompts)
    steps = 0
    while loop.step():
        steps += 1
        assert steps < 50
    assert all(len(o) == 6 for o in loop.outputs)


def test_serve_loop_block_decode_matches_single_step():
    """block>1 dispatch: the host-side bookkeeping must emit exactly the
    per-step loop's tokens, truncated at an EOS that lands MID-block (the
    speculative steps after it are computed but dropped; the EOS itself is
    a stop signal, not an output token)."""
    cfg, model, params = _model()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 64))
    ref = ServeLoop(model, params, lanes=2, prompt_len=64, max_new=6)
    ref.admit(prompts)
    while ref.step():
        pass
    eos = ref.outputs[0][2]          # lane 0 hits EOS at step 2 of block 3

    def trunc(seq):
        return seq[:seq.index(eos)] if eos in seq else seq

    blk = ServeLoop(model, params, lanes=2, prompt_len=64, max_new=6,
                    eos=eos, block=3)
    blk.admit(prompts)
    steps = 0
    while blk.step_block():
        steps += 1
        assert steps <= 2            # 6 tokens / block of 3
    assert blk.outputs == [trunc(s) for s in ref.outputs]


def test_long_generation_keeps_heavy_history_not_just_window():
    """UniCAIM keeps score-selected OLD tokens (vs StreamingLLM's window):
    kept positions include sinks and are not a contiguous recent window."""
    cfg, model, params = _model()
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 80), 0,
                              cfg.vocab_size)
    _, state = jax.jit(model.prefill)(params, {"tokens": toks})
    decode = jax.jit(model.decode_step)
    tok = jnp.zeros((1,), jnp.int32)
    for _ in range(100):
        lg, state = decode(params, state, tok)
        tok = jnp.argmax(lg, -1)
    pos = np.asarray(state.kv.pos[0, 0, 0])
    kept = pos[pos >= 0]
    assert kept.min() < 4            # sinks retained from the start
    assert kept.max() >= 175         # newest tokens present
    spread = np.diff(np.sort(kept))
    assert (spread > 1).any()        # score-based, not a contiguous window
