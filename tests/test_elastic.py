"""Elastic scaling: a checkpoint saved under one mesh restores onto a
DIFFERENT topology with shardings recomputed from logical rules.

Runs in a subprocess so it can claim 8 host devices without polluting the
single-device test session.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.base import get_config, reduced
    from repro.core import baselines
    from repro.models.transformer import Model
    from repro.runtime.sharding import params_pspecs, use_mesh
    from repro.runtime.fault import elastic_restore

    cfg = reduced(get_config("granite-3-2b"), n_heads=4, n_kv_heads=2)
    model = Model(cfg, baselines.unicaim(48, 16, 16))

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh_a):
        params = model.init(jax.random.PRNGKey(0))
        sh = jax.tree.map(lambda s: NamedSharding(mesh_a, s),
                          params_pspecs(params),
                          is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, sh)
    mgr = CheckpointManager("/tmp/elastic_ckpt_test", keep=1,
                            async_save=False)
    mgr.save(7, params, block=True)
    flat_a = [np.asarray(x) for x in jax.tree.leaves(params)]

    # "cluster shrinks": restore onto a 4x2 mesh with recomputed shardings
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    with use_mesh(mesh_b):
        def make_sh():
            return jax.tree.map(lambda s: NamedSharding(mesh_b, s),
                                params_pspecs(template),
                                is_leaf=lambda x: isinstance(x, P))
        restored = elastic_restore(mgr, template, make_sh)
    flat_b = [np.asarray(x) for x in jax.tree.leaves(restored)]
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(a, b)
    # the restored tree really lives on the new mesh
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 4, "model": 2}, \
        leaf.sharding
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_meshes():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "ELASTIC_OK" in out.stdout, (out.stdout[-2000:],
                                        out.stderr[-2000:])
