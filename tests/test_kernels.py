"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode on CPU; identical code targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.approx_score import approx_score
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.gather_attention import gather_attention

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("bh,g,d,s,block", [
    (2, 4, 64, 256, 64),
    (1, 1, 128, 128, 128),
    (3, 8, 32, 512, 256),
    (2, 2, 128, 384, 128),
])
def test_approx_score_sweep(bh, g, d, s, block):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 5)
    qq = jax.random.randint(ks[0], (bh, g, d), -7, 8, jnp.int8)
    kq = jax.random.randint(ks[1], (bh, s, d), -7, 8, jnp.int8)
    qs = jax.random.uniform(ks[2], (bh, g)) + 0.05
    ksc = jax.random.uniform(ks[3], (bh, s)) + 0.05
    valid = jax.random.bernoulli(ks[4], 0.85, (bh, s)).astype(jnp.int8)
    out = approx_score(qq, qs, kq, ksc, valid, block_s=block,
                       interpret=True)
    expect = ref.approx_score_ref(qq, qs, kq, ksc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,g,d,kk,block", [
    (2, 4, 64, 128, 32),
    (1, 8, 128, 256, 256),
    (3, 1, 32, 64, 64),
])
def test_gather_attention_sweep(bh, g, d, kk, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(kk), 4)
    q = jax.random.normal(ks[0], (bh, g, d), dtype)
    k = jax.random.normal(ks[1], (bh, kk, d), dtype)
    v = jax.random.normal(ks[2], (bh, kk, d), dtype)
    valid = jnp.ones((bh, kk), jnp.int8).at[:, -9:].set(0)
    out = gather_attention(q, k, v, valid, block_k=block, interpret=True)
    expect = ref.gather_attention_ref(q, k, v, valid)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=atol)


@pytest.mark.parametrize("b,hq,hk,n,d,bq,bk", [
    (1, 2, 1, 128, 32, 32, 32),
    (2, 4, 2, 128, 64, 64, 32),
    (1, 2, 2, 256, 32, 64, 64),
])
def test_flash_prefill_sweep(b, hq, hk, n, d, bq, bk):
    g = hq // hk
    ks = jax.random.split(jax.random.PRNGKey(n + d), 3)
    q = jax.random.normal(ks[0], (b * hq, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (b * hk, n, d), jnp.float32)
    v = jax.random.normal(ks[2], (b * hk, n, d), jnp.float32)
    out, acc = flash_prefill(q, k, v, group=g, block_q=bq, block_k=bk,
                             interpret=True)
    ref_out, ref_acc = ref.flash_prefill_ref(q, k, v, group=g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref_acc),
                               atol=2e-4)
    # column sums of a causal softmax over N rows total N per (b,h)
    np.testing.assert_allclose(np.asarray(acc.sum(-1)),
                               np.full((b * hq,), float(n)), rtol=1e-4)


def test_flash_prefill_lengths_mask():
    """Bucketed prefill in-kernel: pad rows beyond the per-row true length
    add no column mass; real rows/cols match the exact-length kernel."""
    b, hq, hk, n, d, t = 1, 4, 2, 128, 32, 80
    g = hq // hk
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (b * hq, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (b * hk, n, d), jnp.float32)
    v = jax.random.normal(ks[2], (b * hk, n, d), jnp.float32)
    lengths = jnp.full((b * hq,), t, jnp.int32)
    out, acc = flash_prefill(q, k, v, group=g, block_q=32, block_k=32,
                             interpret=True, lengths=lengths)
    ref_out, ref_acc = ref.flash_prefill_ref(q, k, v, group=g,
                                             lengths=lengths)
    np.testing.assert_allclose(np.asarray(out[:, :t]),
                               np.asarray(ref_out[:, :t]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref_acc),
                               atol=2e-4)
    # pad columns receive no probability mass from real rows
    assert np.abs(np.asarray(acc[:, t:])).max() == 0.0
    # exact-length run over the true prefix agrees on real columns
    out_e, acc_e = flash_prefill(q[:, :t], k[:, :t], v[:, :t], group=g,
                                 block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, :t]), np.asarray(out_e),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(acc[:, :t]), np.asarray(acc_e),
                               atol=2e-4)
    # column sums of a causal softmax over t live rows total t per (b,h)
    np.testing.assert_allclose(np.asarray(acc.sum(-1)),
                               np.full((b * hq,), float(t)), rtol=1e-4)


def test_flash_prefill_bf16():
    b, hq, hk, n, d = 1, 2, 1, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b * hq, n, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b * hk, n, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b * hk, n, d), jnp.bfloat16)
    out, acc = flash_prefill(q, k, v, group=2, block_q=32, block_k=32,
                             interpret=True)
    ref_out, ref_acc = ref.flash_prefill_ref(q, k, v, group=2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32), atol=5e-2)


def test_ops_wrappers_pad_odd_sizes():
    from repro.kernels import ops
    bh, g, d, s = 2, 2, 32, 100        # s not a multiple of block
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    qq = jax.random.randint(ks[0], (bh, g, d), -7, 8, jnp.int8)
    kq = jax.random.randint(ks[1], (bh, s, d), -7, 8, jnp.int8)
    qs = jax.random.uniform(ks[2], (bh, g)) + 0.05
    ksc = jax.random.uniform(ks[3], (bh, s)) + 0.05
    valid = jnp.ones((bh, s), jnp.int8)
    out = ops.approx_score(qq, qs, kq, ksc, valid, block_s=64)
    expect = ref.approx_score_ref(qq, qs, kq, ksc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6)


@pytest.mark.parametrize("bh,g,d,s,block", [
    (2, 4, 64, 256, 64),
    (1, 2, 128, 128, 128),
    (3, 8, 32, 512, 256),
])
def test_approx_score_packed_sweep(bh, g, d, s, block):
    """int4-packed mirror kernel (halved HBM mirror reads) vs oracle."""
    from repro.core.quant import pack_int4
    from repro.kernels.approx_score import approx_score_packed
    ks = jax.random.split(jax.random.PRNGKey(s * 3 + d), 5)
    qq = jax.random.randint(ks[0], (bh, g, d), -7, 8, jnp.int8)
    codes = jax.random.randint(ks[1], (bh, s, d), -8, 8, jnp.int8)
    packed = pack_int4(codes)
    qs = jax.random.uniform(ks[2], (bh, g)) + 0.05
    ksc = jax.random.uniform(ks[3], (bh, s)) + 0.05
    valid = jax.random.bernoulli(ks[4], 0.9, (bh, s)).astype(jnp.int8)
    out = approx_score_packed(qq, qs, packed, ksc, valid, block_s=block,
                              interpret=True)
    expect = ref.approx_score_packed_ref(qq, qs, packed, ksc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6)
