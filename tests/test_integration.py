"""Integration: small model trains (loss decreases), fault-tolerant loop
survives injected failures, serving generates coherently, SSM prefill→decode
continuity, baseline policies rank as the paper claims."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.data.pipeline import SyntheticSource
from repro.launch.serve import generate_scan, greedy_generate
from repro.launch.train import init_train_state, make_train_step
from repro.models.transformer import Model
from repro.optim import adamw
from repro.runtime import fault

jax.config.update("jax_platform_name", "cpu")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16, sink_tokens=2,
                          recent_window=8)


def _tiny_model(arch="granite-3-2b", prune=PRUNE):
    cfg = reduced(get_config(arch))
    return cfg, Model(cfg, prune)


def test_train_loss_decreases():
    cfg, model = _tiny_model()
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt_cfg, total_steps=60,
                                   peak_lr=3e-3, warmup=10))
    src = SyntheticSource(cfg.vocab_size, 64, seed=0)
    losses = []
    for i in range(60):
        batch = {"tokens": jnp.asarray(src.batch(i, 8))}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, \
        (losses[:5], losses[-5:])


def test_fault_tolerant_loop_recovers(tmp_path):
    cfg, model = _tiny_model()
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt_cfg, total_steps=40))
    src = SyntheticSource(cfg.vocab_size, 32, seed=0)
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    crashed = {"done": False}

    def inject(step_i):
        if step_i == 25 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    def data_iter(i):
        return {"tokens": jnp.asarray(src.batch(i, 4))}

    state, stats = fault.run_training(
        step_fn=step, state=state, data_iter=data_iter, num_steps=40,
        ckpt=ckpt,
        fcfg=fault.FaultConfig(ckpt_every=10, max_restarts=2),
        inject_failure=inject)
    assert stats.restarts == 1
    assert int(state.opt.step) == 40         # resumed from 20, reached 40
    assert ckpt.latest_step() == 40


def test_checkpoint_resume_bitexact(tmp_path):
    """Crash/restore replays to an identical state (pure step + determin-
    istic data ⇒ restart transparency)."""
    cfg, model = _tiny_model()
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(model, opt_cfg, total_steps=30))
    src = SyntheticSource(cfg.vocab_size, 32, seed=1)

    def run(n, state):
        for i in range(int(state.opt.step), n):
            state, _ = step(state, {"tokens": jnp.asarray(src.batch(i, 4))})
        return state

    s_direct = run(20, init_train_state(model, opt_cfg,
                                        jax.random.PRNGKey(0)))
    # checkpoint at 10, restore, continue to 20
    s10 = run(10, init_train_state(model, opt_cfg, jax.random.PRNGKey(0)))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(10, s10, block=True)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        s10)
    s_resumed = run(20, mgr.restore(10, like))
    for a, b in zip(jax.tree.leaves(s_direct.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_generate_scan_matches_python_loop():
    cfg, model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, cfg.vocab_size)}
    t1, _ = greedy_generate(model, params, batch, steps=8)
    t2, _ = jax.jit(lambda p, b: generate_scan(model, p, b, 8))(params,
                                                                batch)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_ssm_prefill_decode_continuity():
    """For an SSM, prefill(prompt)+decode(t) must equal prefill(prompt+t)."""
    cfg, model = _tiny_model("mamba2-1.3b", baselines.dense(256))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 33), 0,
                              cfg.vocab_size)
    # path A: prefill 32 then decode token 32
    lg_a, state = model.prefill(params, {"tokens": toks[:, :32]})
    lg_a2, _ = model.decode_step(params, state, toks[:, 32])
    # path B: full forward over 33 tokens
    logits_full, _ = model.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg_a2),
                               np.asarray(logits_full[:, -1]), atol=2e-3)


def test_attention_prefill_decode_continuity_dense():
    """Dense-policy prefill+decode equals the full causal forward."""
    cfg, model = _tiny_model("granite-3-2b", baselines.dense(256))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 41), 0,
                              cfg.vocab_size)
    lg, state = model.prefill(params, {"tokens": toks[:, :40]})
    lg2, _ = model.decode_step(params, state, toks[:, 40])
    logits_full, _ = model.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg2),
                               np.asarray(logits_full[:, -1]), atol=2e-3)


def test_policy_quality_ordering():
    """Paper Fig.13 claim, miniaturised: on a TRAINED model (peaked
    attention) at the same budget, UniCAIM decode logits track dense
    attention better than StreamingLLM's fixed window."""
    from benchmarks.common import tiny_trained_model
    cfg, params, src = tiny_trained_model(steps=60)
    toks = jnp.asarray(src.batch(5000, 2)[:, :96])
    batch = {"tokens": toks}
    dense_m = Model(cfg, baselines.dense(200))
    lg0, _ = jax.jit(dense_m.prefill)(params, batch)

    def drift(prune):
        m = Model(cfg, prune)
        lg, state = jax.jit(m.prefill)(params, batch)
        lg_d, state_d = jax.jit(dense_m.prefill)(params, batch)
        err = 0.0
        tok = jnp.argmax(lg0, -1)
        dec, dec_d = jax.jit(m.decode_step), jax.jit(dense_m.decode_step)
        for i in range(8):
            lg, state = dec(params, state, tok)
            lg_d, state_d = dec_d(params, state_d, tok)
            err += float(jnp.mean(jnp.abs(jax.nn.softmax(lg) -
                                          jax.nn.softmax(lg_d))))
            tok = jnp.argmax(lg_d, -1)
        return err

    budget = 48
    e_uni = drift(baselines.unicaim(heavy=budget, reserve=16, select_k=32,
                                    sink_tokens=2, recent_window=8))
    e_str = drift(baselines.streaming(budget + 16, sinks=2))
    # paper's primary claim: comparable with dense at low cache ratio
    assert e_uni < 0.01, e_uni
    # and never materially worse than the window baseline on local data
    # (the >StreamingLLM gap needs long-range tasks — see
    #  benchmarks/bench_accuracy.py and bench_needle.py for the artifact)
    assert e_uni <= max(e_str * 3.0, 0.01), (e_uni, e_str)
