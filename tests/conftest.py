"""Shared test fixtures + a no-op `hypothesis` fallback.

`hypothesis` is a declared (requirements.txt) but optional dependency:
when it is missing, property tests are skipped instead of breaking
collection of the whole module.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategies:
    """Stand-in for `hypothesis.strategies`: every strategy builder returns
    None; the tests it feeds are skipped by the `given` stub anyway."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
