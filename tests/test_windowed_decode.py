"""Fill-aware ragged decode tests.

Two coordinated mechanisms under test:

  * windowed dispatch — the decode step runs over the `[:W]` slot prefix
    (W = pow2 cover of the live fills) and must be BIT-IDENTICAL to the
    full-slot step across fills, kv dtypes (bf16 + int8 mirror), policies,
    and the ring-buffer wrap boundary, with the window grid bounding the
    retrace count at log2(slots);
  * the ragged Pallas fused-decode kernel — per-lane live-block early
    exit must match `ref.fused_decode_ref` on mixed-fill batches.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_config, reduced
from repro.core import baselines, quant
from repro.core.attention import decode_attention, windowed_decode_attention
from repro.core.cache import (decode_window, init_cache, slot_window,
                              slot_window_merge)
from repro.kernels import ops, ref
from repro.kernels.ragged_decode import ragged_decode
from repro.launch.serve import ServeLoop
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _filled_cache(fills, slots, prune, dtype=jnp.bfloat16, hk=2, d=16,
                  key=0):
    """Per-lane fill prefixes — the layout prefill + append decode make."""
    b = len(fills)
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    c = init_cache(b, hk, d, slots, prune, dtype)
    k = jax.random.normal(ks[0], (b, hk, slots, d))
    v = jax.random.normal(ks[1], (b, hk, slots, d))
    fills = jnp.asarray(fills, jnp.int32)
    live = jnp.arange(slots)[None, None, :] < fills[:, None, None]
    live = jnp.broadcast_to(live, (b, hk, slots))
    pos = jnp.broadcast_to(jnp.arange(slots)[None, None, :],
                           (b, hk, slots))
    acc = jax.random.uniform(ks[2], (b, hk, slots)) * live
    if c.quantized_kv:
        kq8, ksc = quant.quantize(k, 8)
        vq8, vsc = quant.quantize(v, 8)
        c = c._replace(k=jnp.where(live[..., None], kq8, 0),
                       v=jnp.where(live[..., None], vq8, 0),
                       kscale=jnp.where(live, ksc, 0.0),
                       vscale=jnp.where(live, vsc, 0.0))
    else:
        kq, ksc = quant.quantize(k, prune.score_bits)
        c = c._replace(k=jnp.where(live[..., None], k, 0).astype(c.k.dtype),
                       v=jnp.where(live[..., None], v, 0).astype(c.v.dtype),
                       kq=jnp.where(live[..., None], kq, 0),
                       kscale=jnp.where(live, ksc, 0.0))
    return c._replace(acc=acc, valid=live,
                      pos=jnp.where(live, pos, -1),
                      fill=fills, step=fills)


# -- decode_window grid -------------------------------------------------------


def test_decode_window_grid():
    prune = PruneConfig(policy="unicaim", heavy_budget=4032, reserve=64,
                        select_k=64, sink_tokens=2, recent_window=8)
    assert decode_window(128, 1, 4096, prune) == 256
    assert decode_window(100, 28, 4096, prune) == 128
    assert decode_window(0, 1, 4096, prune) == 64        # select_k floor
    assert decode_window(4000, 8, 4096, prune) is None   # full lane
    assert decode_window(2049, 1, 4096, prune) is None   # pow2 hits slots
    # non-pow2 block race can't partition a pow2 window → full width
    nb3 = dataclasses.replace(prune, select_blocks=3, select_k=63)
    assert decode_window(10, 1, 4096, nb3) is None
    nb2 = dataclasses.replace(prune, select_blocks=2)
    assert decode_window(128, 1, 4096, nb2) == 256


def test_slot_window_roundtrip_stacked():
    """slot_window/merge must be each other's inverse on layer-stacked
    caches (the DecodeState layout) for every field."""
    prune = baselines.unicaim(heavy=24, reserve=8, select_k=8,
                              sink_tokens=2, recent_window=4)
    c = _filled_cache([5, 12], prune.slots, prune, key=3)
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), c)
    win = slot_window(stacked, 16)
    assert win.k.shape[-2] == 16 and win.acc.shape[-1] == 16
    _assert_trees_equal(slot_window_merge(stacked, win), stacked)


# -- windowed step: bitwise parity --------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("policy,select_mode,fused", [
    ("unicaim", "topk", False),
    ("unicaim", "topk", True),
    ("unicaim", "threshold", False),
    ("h2o", "topk", False),
    ("dense", "topk", False),
])
def test_windowed_step_bitwise_parity(kv_dtype, policy, select_mode, fused):
    """Windowed decode == full-slot decode, bit for bit, across fills and
    multiple steps (each step appends into the window)."""
    if policy != "unicaim" and kv_dtype == "int8":
        pytest.skip("int8 KV is a unicaim-mode knob")
    prune = PruneConfig(policy=policy, heavy_budget=48, reserve=16,
                        sink_tokens=2, recent_window=4, select_k=8,
                        select_mode=select_mode, kv_dtype=kv_dtype,
                        fused=fused, fused_backend="xla",
                        accumulate="exact" if policy == "h2o" else "approx")
    for fills in ([3, 9], [0, 20], [16, 28]):
        cw = cf = _filled_cache(fills, prune.slots, prune,
                                dtype=jnp.bfloat16, key=sum(fills))
        w = decode_window(max(fills), 3, prune.slots, prune)
        assert w is not None and w < prune.slots
        step_w = jax.jit(lambda c, q, k, v: windowed_decode_attention(
            c, q, k, v, prune, w))
        step_f = jax.jit(lambda c, q, k, v: decode_attention(
            c, q, k, v, prune))
        for i in range(3):
            ks = jax.random.split(jax.random.PRNGKey(100 + i), 3)
            q = jax.random.normal(ks[0], (2, 4, 16))
            kn = jax.random.normal(ks[1], (2, 2, 16))
            vn = jax.random.normal(ks[2], (2, 2, 16))
            cw, ow = step_w(cw, q, kn, vn)
            cf, of = step_f(cf, q, kn, vn)
            np.testing.assert_array_equal(np.asarray(ow), np.asarray(of))
            _assert_trees_equal(cw, cf)


@pytest.mark.parametrize("policy", ["unicaim", "streaming"])
def test_ring_wrap_boundary_forces_full_width(policy):
    """At the wrap/eviction boundary the window must be the full slot
    array: decode_window refuses a window there, and steps that overwrite
    slots (ring wrap for streaming, argmin eviction for unicaim) stay
    bit-identical between the windowed entry point (window=None) and the
    plain full step."""
    prune = (baselines.streaming(28, sinks=2) if policy == "streaming"
             else baselines.unicaim(heavy=24, reserve=8, select_k=8,
                                    sink_tokens=2, recent_window=4))
    slots = prune.slots
    # one step before full: any window would have to cover slots → None
    assert decode_window(slots - 1, 1, slots, prune) is None
    assert decode_window(slots, 4, slots, prune) is None
    cw = cf = _filled_cache([slots, slots - 1], slots, prune,
                            dtype=jnp.float32, key=7)
    step_w = jax.jit(lambda c, q, k, v: windowed_decode_attention(
        c, q, k, v, prune, None))
    step_f = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))
    for i in range(4):                      # crosses full → wraps/evicts
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        q = jax.random.normal(ks[0], (2, 2, 16))
        kn = jax.random.normal(ks[1], (2, 2, 16))
        vn = jax.random.normal(ks[2], (2, 2, 16))
        cw, ow = step_w(cw, q, kn, vn)
        cf, of = step_f(cf, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(ow), np.asarray(of))
        _assert_trees_equal(cw, cf)
    assert int(np.asarray(cw.fill).max()) == slots


# -- model + serving level ----------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_model_windowed_decode_step_parity(kv_dtype):
    """Model.decode_step(window=W) — slicing + layer scan + merge — is
    bitwise the full-width step: logits and every DecodeState leaf."""
    cfg = reduced(get_config("longchat-7b"))
    prune = dataclasses.replace(
        baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8),
        kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
             "length": jnp.asarray([9, 26], jnp.int32)}
    logits, state_w = jax.jit(model.prefill)(params, batch)
    state_f = state_w
    tw = tf = jnp.argmax(logits, -1)
    step = jax.jit(model.decode_step, static_argnames=("window",))
    for _ in range(4):
        lw, state_w = step(params, state_w, tw, window=32)
        lf, state_f = step(params, state_f, tf, window=None)
        np.testing.assert_array_equal(np.asarray(lw), np.asarray(lf))
        tw, tf = jnp.argmax(lw, -1), jnp.argmax(lf, -1)
    _assert_trees_equal(state_w, state_f)


def test_serve_windowed_parity_and_retrace_bound():
    """ServeLoop(window='auto') emits the exact tokens of window=None and
    compiles at most log2(slots) + 1 distinct windowed block programs
    (the pow2 grid is the retrace bound)."""
    cfg = reduced(get_config("longchat-7b"))
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, t) for t in (9, 25, 12, 40)]

    def run(window):
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=4,
                         window=window)
        rids = [loop.submit(p, max_new=12) for p in prompts]
        done = {s.rid: s for s in loop.run()}
        return [done[r].tokens for r in rids], loop

    toks_w, loop_w = run("auto")
    toks_f, loop_f = run(None)
    assert toks_w == toks_f
    assert loop_w.counters["decode_windows"] >= 1
    assert (loop_w.counters["decode_windows"]
            <= math.ceil(math.log2(prune.slots)) + 1)
    assert loop_f.counters["decode_windows"] <= 1     # {None}


# -- ragged fused-decode kernel ----------------------------------------------


def _ragged_args(bh, g, d, dv, s, fills, key=0, quantized=False,
                 prot_frac=0.1):
    ks = jax.random.split(jax.random.PRNGKey(key), 10)
    q = jax.random.normal(ks[0], (bh, g, d))
    qq = jax.random.randint(ks[1], (bh, g, d), -7, 8, jnp.int8)
    qs = jax.random.uniform(ks[2], (bh, g)) + 0.05
    mirror = jax.random.randint(ks[3], (bh, s, d), -7, 8, jnp.int8)
    ms = jax.random.uniform(ks[4], (bh, s)) + 0.05
    if quantized:
        k = jax.random.randint(ks[5], (bh, s, d), -127, 128, jnp.int8)
        v = jax.random.randint(ks[6], (bh, s, dv), -127, 128, jnp.int8)
        kscale = jax.random.uniform(ks[7], (bh, s)) * 0.02 + 0.001
        vscale = jax.random.uniform(ks[8], (bh, s)) * 0.02 + 0.001
    else:
        k = jax.random.normal(ks[5], (bh, s, d))
        v = jax.random.normal(ks[6], (bh, s, dv))
        kscale = jnp.ones((bh, s))
        vscale = jnp.ones((bh, s))
    fills = jnp.asarray(fills, jnp.int32)
    valid = (jnp.arange(s)[None, :] < fills[:, None]).astype(jnp.int8)
    prot = (jax.random.bernoulli(ks[9], prot_frac,
                                 (bh, s)).astype(jnp.int8)) * valid
    return fills, (q, qq, qs, mirror, ms, kscale, vscale, valid, prot, k, v)


@pytest.mark.parametrize("bh,g,d,dv,s,sk,fills,quantized", [
    (4, 2, 32, 32, 64, 16, [5, 30, 64, 0], False),   # mixed + empty + full
    (3, 4, 16, 24, 100, 8, [100, 17, 42], True),     # int8, ragged S
    (2, 1, 16, 16, 48, 48, [10, 48], False),         # select_k == S
])
def test_ragged_kernel_matches_ref(bh, g, d, dv, s, sk, fills, quantized):
    """Dead-block early exit must not change a bit of the math: parity
    with the global-selection oracle on per-lane fill prefixes."""
    fl, args = _ragged_args(bh, g, d, dv, s, fills, key=s + sk,
                            quantized=quantized)
    out_k, probs_k = ragged_decode(fl, *args, select_k=sk, block_s=16,
                                   interpret=True)
    out_r, probs_r = ref.fused_decode_ref(*args, select_k=sk, num_blocks=1)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs_k), np.asarray(probs_r),
                               atol=1e-6)
    # probs at dead slots are exactly zero (they feed the acc table)
    dead = np.arange(s)[None, :] >= np.asarray(fl)[:, None]
    assert not np.asarray(probs_k)[dead].any()


def test_ops_fused_decode_dispatches_ragged():
    """ops.fused_decode(fills=..., backend='pallas') must route through
    the ragged kernel (global selection) and match the XLA fallback."""
    fl, args = _ragged_args(3, 2, 16, 16, 40, [7, 22, 40], key=11)
    out_r, probs_r = ops.fused_decode(*args, select_k=8, num_blocks=1,
                                      backend="pallas", fills=fl)
    out_x, probs_x = ops.fused_decode(*args, select_k=8, num_blocks=1,
                                      backend="xla", fills=fl)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_x),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs_r), np.asarray(probs_x),
                               atol=1e-6)


def test_fused_auto_resolves_per_backend():
    """fused='auto' must resolve to the composed path off-TPU (the XLA
    fallback was measured at parity-to-slower) and stay a valid
    PruneConfig value."""
    from repro.core.attention import _fused_enabled, fused_auto_decision
    prune = dataclasses.replace(
        baselines.unicaim(heavy=24, reserve=8, select_k=8, sink_tokens=2,
                          recent_window=4), fused="auto")
    prune.validate()
    decision = fused_auto_decision()
    assert decision["engine"] in ("fused", "composed")
    assert decision["reason"]
    on_tpu = jax.default_backend() == "tpu"
    assert _fused_enabled(prune) == on_tpu
    assert _fused_enabled(dataclasses.replace(prune, fused=True))
    assert not _fused_enabled(dataclasses.replace(prune, fused=False))
