"""Unit + property tests for signed multibit quantization (the CAM cell)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property tests skip, the rest still run
    from conftest import given, settings, st  # noqa: F401

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 8])
def test_quantize_roundtrip_error_bound(bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
    q, s = quant.quantize(x, bits)
    xhat = quant.dequantize(q, s)
    if bits == 1:
        # sign quantization preserves sign wherever the scale is positive
        assert jnp.all((xhat >= 0) == (x >= 0) | (jnp.abs(x) < 1e-7))
    else:
        qm = quant.qmax_for_bits(bits)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        assert jnp.all(jnp.abs(xhat - x) <= amax / qm * 0.5 + 1e-6)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_quantize_codes_in_range(bits):
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * 100
    q, _ = quant.quantize(x, bits)
    qm = quant.qmax_for_bits(bits)
    assert int(jnp.max(q)) <= qm and int(jnp.min(q)) >= -qm


def test_pack_unpack_int4_roundtrip():
    q = jax.random.randint(jax.random.PRNGKey(2), (3, 5, 32), -8, 8,
                           jnp.int8)
    packed = quant.pack_int4(q)
    assert packed.shape == (3, 5, 16)
    assert jnp.array_equal(quant.unpack_int4(packed), q)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(1, 7),
       st.floats(0.1, 100.0))
def test_property_dot_product_preserved(bits, dim_pow, scale):
    """Quantized score correlates with exact score (the CAM guarantee)."""
    d = 2 ** dim_pow
    key = jax.random.PRNGKey(bits * 100 + dim_pow)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (d,)) * scale
    b = jax.random.normal(k2, (d,)) * scale
    qa, sa = quant.quantize(a[None], bits)
    qb, sb = quant.quantize(b[None], bits)
    approx = float(jnp.sum(qa[0].astype(jnp.int32) * qb[0].astype(jnp.int32))
                   * sa[0] * sb[0])
    exact = float(jnp.dot(a, b))
    qm = quant.qmax_for_bits(bits)
    # per-element error ≤ 0.5 step on each side → bounded bilinear error
    bound = (float(jnp.max(jnp.abs(a))) * float(jnp.max(jnp.abs(b)))
             * d * (1.2 / qm + 0.3 / qm ** 2)) + 1e-3
    assert abs(approx - exact) <= bound


def test_mirror_bytes():
    assert quant.mirror_bytes_per_token(128, 3) == 64 + 4   # packed nibbles
    assert quant.mirror_bytes_per_token(128, 8) == 128 + 4


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8))
def test_property_scale_invariance(bits):
    """quantize(c·x) has codes equal to quantize(x) (symmetric scheme)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 32))
    q1, s1 = quant.quantize(x, bits)
    q2, s2 = quant.quantize(x * 3.0, bits)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1) * 3.0,
                               rtol=1e-5)
