"""Continuous-batching serving engine tests.

Covers the lane-granular machinery end to end: per-lane cache surgery
(every KVCache field, incl. quantized mirrors), lane-inserted prefill vs
fresh full-batch prefill parity, staggered admission with lane recycling,
variable prompt lengths (lane isolation), EOS landing mid-block with
in-device termination, and metrics sanity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.core import cache as kvcache
from repro.launch.serve import ServeLoop
from repro.models import transformer as T
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-3-2b"))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


# -- per-lane cache surgery ---------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_cache_lane_helpers_roundtrip(kv_dtype):
    """slice→reset→insert restores a written cache exactly, every field."""
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    b, hk, d = 3, 2, 8
    cache = kvcache.init_cache(b, hk, d, prune.slots, prune, jnp.float32)
    key = jax.random.PRNGKey(0)
    for i in range(5):
        k, v = jax.random.normal(jax.random.fold_in(key, i), (2, b, hk, d))
        cache = kvcache.write_token(cache, k, v, prune)
    lane = kvcache.lane_slice(cache, 1)
    for f, g in zip(lane, kvcache.init_cache(1, hk, d, prune.slots, prune,
                                             jnp.float32)):
        if f is not None:
            assert f.shape == g.shape and f.dtype == g.dtype
    wiped = kvcache.lane_reset(cache, 1)
    assert int(np.asarray(wiped.valid)[1].sum()) == 0
    assert int(np.asarray(wiped.fill)[1]) == 0
    assert (np.asarray(wiped.pos)[1] == -1).all()
    # the other lanes are untouched by the reset
    np.testing.assert_array_equal(np.asarray(wiped.k)[0],
                                  np.asarray(cache.k)[0])
    restored = kvcache.lane_insert(wiped, 1, lane)
    for a, b_ in zip(restored, cache):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# -- lane-inserted prefill parity --------------------------------------------


def test_lane_insert_prefill_parity(setup):
    """Per-request prefill_one + lane_insert must reproduce a fresh
    full-batch prefill: logits, every cache field, and the next decode
    step (the ISSUE acceptance criterion)."""
    cfg, model, params = setup
    prompts = np.stack([_prompt(cfg, 40, seed=s) for s in range(3)])
    logits_full, state_full = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompts)})
    prefill_one = jax.jit(model.prefill_one)
    state = model.init_decode_state(3)
    lane_logits = []
    for i in range(3):
        lg, fresh = prefill_one(params, jnp.asarray(prompts[i]))
        state = T.lane_insert(state, i, fresh)
        lane_logits.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(lane_logits)),
                               np.asarray(logits_full), rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state_full)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)
    tok = jnp.argmax(logits_full, -1)
    decode = jax.jit(model.decode_step)
    lg1, _ = decode(params, state, tok)
    lg2, _ = decode(params, state_full, tok)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)


def test_lane_slice_roundtrip(setup):
    cfg, model, params = setup
    prompts = np.stack([_prompt(cfg, 24, seed=s) for s in range(2)])
    _, state = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompts)})
    lane1 = T.lane_slice(state, 1)
    back = T.lane_insert(state, 1, lane1)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- continuous serving -------------------------------------------------------


def test_staggered_admission_and_lane_recycling(setup):
    """5 variable-length requests on 2 lanes: every request completes with
    exactly its own budget, and lanes are freed + refilled mid-flight."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    lens = [24, 32, 24, 40, 32]
    budgets = [3, 5, 4, 3, 6]
    rids = [loop.submit(_prompt(cfg, t, seed=i), max_new=mn)
            for i, (t, mn) in enumerate(zip(lens, budgets))]
    done = loop.run()
    assert sorted(s.rid for s in done) == sorted(rids)
    by_rid = {s.rid: s for s in done}
    for rid, t, mn in zip(rids, lens, budgets):
        assert by_rid[rid].prompt_len == t
        assert len(by_rid[rid].tokens) == mn
    # 5 requests over 2 lanes → at least one lane served >= 2 requests
    assert {s.lane for s in done} == {0, 1}
    assert not loop.active.any() and not loop.queue


def test_variable_length_lane_isolation(setup):
    """A request's tokens must not depend on what shares the batch: served
    alone vs alongside a different-length neighbour gives identical
    output (lanes are independent; empty lanes are harmless)."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 32, seed=7)
    solo = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid = solo.submit(prompt, max_new=6)
    ref = {s.rid: s.tokens for s in solo.run()}[rid]
    both = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid2 = both.submit(prompt, max_new=6)
    both.submit(_prompt(cfg, 24, seed=8), max_new=4)
    out = {s.rid: s.tokens for s in both.run()}[rid2]
    assert out == ref


def test_eos_mid_block_in_device_termination(setup):
    """EOS landing mid-block truncates the lane's output in-device; tokens
    up to and including EOS match the eos-disabled reference."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 24, seed=3)
    ref_loop = ServeLoop(model, params, lanes=2, eos=-1, block=1)
    rid = ref_loop.submit(prompt, max_new=8)
    ref = {s.rid: s.tokens for s in ref_loop.run()}[rid]
    eos = ref[3]                      # EOS fires at step 3 of an 8-block
    expected = ref[:ref.index(eos) + 1]
    loop = ServeLoop(model, params, lanes=2, eos=eos, block=8)
    rid2 = loop.submit(prompt, max_new=8)
    out = {s.rid: s.tokens for s in loop.run()}[rid2]
    assert out == expected
    assert out[-1] == eos


def test_submit_keeps_queue_arrival_ordered(setup):
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2)
    loop.submit(_prompt(cfg, 24), arrival=0.5)
    loop.submit(_prompt(cfg, 24), arrival=0.0)
    loop.submit(_prompt(cfg, 24), arrival=0.5)
    assert [r.arrival for r in loop.queue] == [0.0, 0.5, 0.5]


def test_metrics_sanity(setup):
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    for i, (t, mn) in enumerate(((24, 4), (32, 6), (24, 3), (24, 0))):
        loop.submit(_prompt(cfg, t, seed=20 + i), max_new=mn)
    done = loop.run()
    agg = loop.aggregate()
    assert agg["requests"] == 4
    assert agg["tokens"] == sum(len(s.tokens) for s in done) == 13
    assert agg["tokens_per_s"] > 0
    assert agg["wall_s"] > 0
    assert 0 < agg["mean_occupancy"] <= 1
    for s in done:
        assert len(s.tokens) == s.max_new    # incl. the prefill-only one
        assert 0 <= s.t_admit <= s.t_done
        assert s.latency > 0
        assert 0 < s.occupancy <= 1
        if s.tokens:
            assert s.t_admit <= s.t_first <= s.t_done
            assert s.decode_tps > 0
    # a prefill-only request as the ONLY work must complete, not crash
    solo = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    solo.submit(_prompt(cfg, 24, seed=30), max_new=0)
    only = solo.run()
    assert len(only) == 1 and only[0].tokens == []
    assert not solo.active.any() and not solo.queue
