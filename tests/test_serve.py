"""Continuous-batching serving engine tests.

Covers the lane-granular machinery end to end: per-lane cache surgery
(every KVCache field, incl. quantized mirrors), lane-inserted prefill vs
fresh full-batch prefill parity, staggered admission with lane recycling,
variable prompt lengths (lane isolation), EOS landing mid-block with
in-device termination, and metrics sanity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.core import cache as kvcache
from repro.launch.serve import ServeLoop
from repro.models import transformer as T
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-3-2b"))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


# -- per-lane cache surgery ---------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_cache_lane_helpers_roundtrip(kv_dtype):
    """slice→reset→insert restores a written cache exactly, every field."""
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    b, hk, d = 3, 2, 8
    cache = kvcache.init_cache(b, hk, d, prune.slots, prune, jnp.float32)
    key = jax.random.PRNGKey(0)
    for i in range(5):
        k, v = jax.random.normal(jax.random.fold_in(key, i), (2, b, hk, d))
        cache = kvcache.write_token(cache, k, v, prune)
    lane = kvcache.lane_slice(cache, 1)
    for f, g in zip(lane, kvcache.init_cache(1, hk, d, prune.slots, prune,
                                             jnp.float32)):
        if f is not None:
            assert f.shape == g.shape and f.dtype == g.dtype
    wiped = kvcache.lane_reset(cache, 1)
    assert int(np.asarray(wiped.valid)[1].sum()) == 0
    assert int(np.asarray(wiped.fill)[1]) == 0
    assert (np.asarray(wiped.pos)[1] == -1).all()
    # the other lanes are untouched by the reset
    np.testing.assert_array_equal(np.asarray(wiped.k)[0],
                                  np.asarray(cache.k)[0])
    restored = kvcache.lane_insert(wiped, 1, lane)
    for a, b_ in zip(restored, cache):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_lanes_insert_multi_lane_roundtrip(kv_dtype):
    """One vectorized `lanes_insert` must equal sequential `lane_insert`
    calls for EVERY cache field (incl. quantized mirrors/scales and the
    accumulated scores), and -1 source-map entries must leave their lane
    untouched."""
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    b, hk, d = 4, 2, 8
    key = jax.random.PRNGKey(0)
    live = kvcache.init_cache(b, hk, d, prune.slots, prune, jnp.float32)
    fresh = kvcache.init_cache(3, hk, d, prune.slots, prune, jnp.float32)
    for i in range(6):
        k, v = jax.random.normal(jax.random.fold_in(key, i), (2, b, hk, d))
        live = kvcache.write_token(live, k, v, prune)
        k2, v2 = jax.random.normal(jax.random.fold_in(key, 100 + i),
                                   (2, 3, hk, d))
        fresh = kvcache.write_token(fresh, k2, v2, prune)
    # lanes 3, 0, 1 take fresh rows 0, 1, 2; lane 2 keeps its contents
    src = np.array([1, 2, -1, 0], np.int32)
    got = kvcache.lanes_insert(live, src, fresh)
    want = live
    for lane, row in ((3, 0), (0, 1), (1, 2)):
        want = kvcache.lane_insert(want, lane, kvcache.lane_slice(fresh, row))
    for name, a, b_ in zip(got._fields, got, want):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                          err_msg=name)


# -- lane-inserted prefill parity --------------------------------------------


def test_lane_insert_prefill_parity(setup):
    """Per-request prefill_one + lane_insert must reproduce a fresh
    full-batch prefill: logits, every cache field, and the next decode
    step (the ISSUE acceptance criterion)."""
    cfg, model, params = setup
    prompts = np.stack([_prompt(cfg, 40, seed=s) for s in range(3)])
    logits_full, state_full = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompts)})
    prefill_one = jax.jit(model.prefill_one)
    state = model.init_decode_state(3)
    lane_logits = []
    for i in range(3):
        lg, fresh = prefill_one(params, jnp.asarray(prompts[i]))
        state = T.lane_insert(state, i, fresh)
        lane_logits.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(lane_logits)),
                               np.asarray(logits_full), rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state_full)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)
    tok = jnp.argmax(logits_full, -1)
    decode = jax.jit(model.decode_step)
    lg1, _ = decode(params, state, tok)
    lg2, _ = decode(params, state_full, tok)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)


def test_lane_slice_roundtrip(setup):
    cfg, model, params = setup
    prompts = np.stack([_prompt(cfg, 24, seed=s) for s in range(2)])
    _, state = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompts)})
    lane1 = T.lane_slice(state, 1)
    back = T.lane_insert(state, 1, lane1)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- continuous serving -------------------------------------------------------


def test_staggered_admission_and_lane_recycling(setup):
    """5 variable-length requests on 2 lanes: every request completes with
    exactly its own budget, and lanes are freed + refilled mid-flight."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    lens = [24, 32, 24, 40, 32]
    budgets = [3, 5, 4, 3, 6]
    rids = [loop.submit(_prompt(cfg, t, seed=i), max_new=mn)
            for i, (t, mn) in enumerate(zip(lens, budgets))]
    done = loop.run()
    assert sorted(s.rid for s in done) == sorted(rids)
    by_rid = {s.rid: s for s in done}
    for rid, t, mn in zip(rids, lens, budgets):
        assert by_rid[rid].prompt_len == t
        assert len(by_rid[rid].tokens) == mn
    # 5 requests over 2 lanes → at least one lane served >= 2 requests
    assert {s.lane for s in done} == {0, 1}
    assert not loop.active.any() and not loop.queue


def test_variable_length_lane_isolation(setup):
    """A request's tokens must not depend on what shares the batch: served
    alone vs alongside a different-length neighbour gives identical
    output (lanes are independent; empty lanes are harmless)."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 32, seed=7)
    solo = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid = solo.submit(prompt, max_new=6)
    ref = {s.rid: s.tokens for s in solo.run()}[rid]
    both = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid2 = both.submit(prompt, max_new=6)
    both.submit(_prompt(cfg, 24, seed=8), max_new=4)
    out = {s.rid: s.tokens for s in both.run()}[rid2]
    assert out == ref


def test_eos_mid_block_in_device_termination(setup):
    """EOS landing mid-block truncates the lane's output in-device; tokens
    strictly BEFORE EOS match the eos-disabled reference — the EOS token
    is a stop signal, not an output, so it never counts toward tokens/s."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 24, seed=3)
    ref_loop = ServeLoop(model, params, lanes=2, eos=-1, block=1)
    rid = ref_loop.submit(prompt, max_new=8)
    ref = {s.rid: s.tokens for s in ref_loop.run()}[rid]
    eos = ref[3]                      # EOS fires at step 3 of an 8-block
    expected = ref[:ref.index(eos)]
    loop = ServeLoop(model, params, lanes=2, eos=eos, block=8)
    rid2 = loop.submit(prompt, max_new=8)
    out = {s.rid: s.tokens for s in loop.run()}[rid2]
    assert out == expected
    assert eos not in out


def test_eos_vs_budget_token_counts(setup):
    """EOS-terminated requests report only pre-EOS tokens (no EOS
    inflation of decode_tps / tokens_per_s); budget-terminated requests
    still emit exactly max_new."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 24, seed=3)
    ref_loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid = ref_loop.submit(prompt, max_new=8)
    ref = {s.rid: s.tokens for s in ref_loop.run()}[rid]
    assert len(ref) == 8              # budget-terminated: exactly max_new
    eos = ref[3]
    loop = ServeLoop(model, params, lanes=2, eos=eos, block=2)
    rid_eos = loop.submit(prompt, max_new=8)                 # hits EOS at 3
    other = _prompt(cfg, 32, seed=4)
    rid_budget = loop.submit(other, max_new=5)               # budget-bound
    done = {s.rid: s for s in loop.run()}
    assert len(done[rid_eos].tokens) == 3                    # excl. EOS
    assert done[rid_eos].tokens == ref[:3]
    assert len(done[rid_budget].tokens) == 5
    agg = loop.aggregate()
    assert agg["tokens"] == 8                                # 3 + 5, no EOS


def test_submit_keeps_queue_arrival_ordered(setup):
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2)
    loop.submit(_prompt(cfg, 24), arrival=0.5)
    loop.submit(_prompt(cfg, 24), arrival=0.0)
    loop.submit(_prompt(cfg, 24), arrival=0.5)
    assert [r.arrival for r in loop.queue] == [0.0, 0.5, 0.5]


# -- shape-stable bucketed prefill -------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_bucketed_prefill_parity(kv_dtype):
    """Bucketed (right-padded + true-length-masked) prefill must be
    bit-identical to a same-bucket full-batch prefill — logits and every
    cache field, incl. the quantized mirrors — and must match the
    exact-length oracle to float-association noise. Covers a prompt
    SHORTER than sink_tokens + recent_window and one shorter than the
    heavy budget (inert pad slots)."""
    cfg = reduced(get_config("granite-3-2b"))
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(model.prefill)
    prefill_one = jax.jit(model.prefill_one)
    bucket = 64
    lens = [40, 37, 8]                # 8 < sink_tokens + recent_window = 10
    prompts = [_prompt(cfg, t, seed=50 + i) for i, t in enumerate(lens)]
    padded = np.zeros((len(lens), bucket), np.int64)
    for i, p in enumerate(prompts):
        padded[i, :len(p)] = p

    # bucketed full-batch (mixed true lengths in one batch)
    lg_full, st_full = prefill(params, {"tokens": jnp.asarray(padded),
                                        "length": jnp.asarray(lens)})
    # lane-inserted bucketed prefill_one — BIT-identical
    state = model.init_decode_state(len(lens))
    for i, t in enumerate(lens):
        lg1, fresh = prefill_one(params, jnp.asarray(padded[i]),
                                 jnp.asarray(t))
        state = T.lane_insert(state, i, fresh)
        np.testing.assert_array_equal(np.asarray(lg1),
                                      np.asarray(lg_full[i]))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(st_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # vs the exact-length oracle: logits + every cache field to float
    # tolerance, all structural fields exactly
    for i, (t, prompt) in enumerate(zip(lens, prompts)):
        lg_e, st_e = prefill(params, {"tokens": jnp.asarray(prompt[None])})
        np.testing.assert_allclose(np.asarray(lg_full[i]),
                                   np.asarray(lg_e[0]),
                                   rtol=1e-5, atol=1e-5)
        kv_b = T.lane_slice(st_full, i).kv
        for name, a, b in zip(kv_b._fields, kv_b, st_e.kv):
            if a is None:
                continue
            a, b = np.asarray(a)[:, 0], np.asarray(b)[:, 0]
            if name in ("valid", "pos", "fill", "step"):
                np.testing.assert_array_equal(a, b, err_msg=name)
            elif np.issubdtype(a.dtype, np.integer):
                # int8 codes: float-association noise may flip a rounding
                # boundary by one level
                np.testing.assert_allclose(a.astype(np.int32),
                                           b.astype(np.int32), atol=1,
                                           err_msg=name)
            else:
                np.testing.assert_allclose(a.astype(np.float32),
                                           b.astype(np.float32),
                                           rtol=1e-4, atol=1e-5,
                                           err_msg=name)
    # the short prompt filled exactly its true length, not the bucket
    assert np.asarray(st_full.kv.fill)[:, 2].max() == 8
    assert np.asarray(st_full.kv.step)[:, 2].max() == 8


def test_bucketed_prefill_bounds_compiles(setup):
    """ISSUE acceptance: serving >= 8 distinct prompt lengths compiles a
    bounded number of prefill programs — at most 1 + log2(lanes) per
    bucket (one batch-1 single-admission program + one per power-of-two
    group size; == 2/bucket at lanes=2; which buckets use which depends
    only on scheduling, never on how many distinct lengths the traffic
    carries) — and the generated tokens match the exact-length
    (unbucketed) engine."""
    cfg, _, _ = setup
    # fresh Prune/Model identity → fresh process-wide jit caches, so the
    # cache-size counter below counts only THIS test's compiles
    prune = dataclasses.replace(PRUNE, select_k=24)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    lens = [9, 12, 17, 24, 31, 40, 47, 63, 64]        # 9 distinct lengths
    buckets = (16, 32, 64)
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                     buckets=buckets)
    exact = ServeLoop(model, params, lanes=2, eos=-1, block=2, buckets=None)
    rids, rids_e = [], []
    for i, t in enumerate(lens):
        prompt = _prompt(cfg, t, seed=80 + i)
        rids.append(loop.submit(prompt, max_new=3))
        rids_e.append(exact.submit(prompt, max_new=3))
    done = {s.rid: s for s in loop.run()}
    programs = loop.prefill_programs()
    assert programs["jit_cache"] <= 2 * len(buckets)
    assert programs["loop_shapes"] <= 2 * len(buckets)
    assert {done[r].bucket for r in rids} == {16, 32, 64}
    # the exact-length engine compiles one program per distinct length...
    done_e = {s.rid: s for s in exact.run()}
    assert exact.prefill_programs()["loop_shapes"] == len(set(lens))
    # ...and bucketing changes nothing the user can see
    for r, re_ in zip(rids, rids_e):
        assert done[r].tokens == done_e[re_].tokens


# -- grouped admission --------------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_grouped_admission_bitwise_parity(kv_dtype):
    """ISSUE acceptance: one `prefill_group` + `lanes_insert` dispatch
    must be BIT-identical to admitting the same requests sequentially via
    `prefill_one` + `lane_insert` — logits, seeded tokens, and every
    cache field — including when the group is padded with a duplicate row
    (G < lanes) whose output is discarded."""
    cfg = reduced(get_config("granite-3-2b"))
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    lanes, bucket = 4, 64
    lens = [40, 37, 8]                 # G=3 < lanes → one padded dummy row
    prompts = [_prompt(cfg, t, seed=90 + i) for i, t in enumerate(lens)]
    rows = np.zeros((lanes, bucket), np.int64)
    for i, p in enumerate(prompts):
        rows[i, :len(p)] = p
    rows[3, :lens[0]] = prompts[0]     # duplicate row 0, as ServeLoop pads
    lengths = np.array(lens + [lens[0]], np.int32)

    lg_g, fresh = jax.jit(model.prefill_group)(params, jnp.asarray(rows),
                                               jnp.asarray(lengths))
    src = np.array([-1, 0, 2, 1], np.int32)   # lanes 1,3,2 take rows 0,1,2
    state_g = T.lanes_insert(model.init_decode_state(lanes),
                             jnp.asarray(src), fresh)

    prefill_one = jax.jit(model.prefill_one)
    state_s = model.init_decode_state(lanes)
    for lane, row in ((1, 0), (3, 1), (2, 2)):
        lg1, one = prefill_one(params, jnp.asarray(rows[row]),
                               jnp.asarray(lengths[row]))
        state_s = T.lane_insert(state_s, lane, one)
        np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg_g[row]))
    for name, a, b in zip(state_g.kv._fields, state_g.kv, state_s.kv):
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_grouped_vs_sequential_engine_parity(kv_dtype):
    """End-to-end: a bursty same-bucket arrival set served with grouped
    admission produces exactly the sequential engine's tokens, with
    strictly fewer prefill and admit dispatches."""
    cfg = reduced(get_config("granite-3-2b"))
    prune = dataclasses.replace(PRUNE, kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    # equal budgets → paired lanes always free together, so the dispatch
    # count below is deterministic (unequal budgets still group, but a
    # lone freed lane refills solo mid-flight)
    reqs = [(40, 4), (37, 4), (33, 4), (38, 4), (36, 4), (35, 4)]
    grouped = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    seq = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                    group_admit=False)
    rid_g, rid_s = [], []
    for i, (t, mn) in enumerate(reqs):
        prompt = _prompt(cfg, t, seed=40 + i)
        rid_g.append(grouped.submit(prompt, max_new=mn))
        rid_s.append(seq.submit(prompt, max_new=mn))
    done_g = {s.rid: s for s in grouped.run()}
    done_s = {s.rid: s for s in seq.run()}
    for rg, rs in zip(rid_g, rid_s):
        assert done_g[rg].tokens == done_s[rs].tokens
    # all six pad to bucket 64 → admitted in pairs: 3 dispatches, not 6
    assert grouped.counters["prefill_dispatches"] == 3
    assert grouped.counters["admit_dispatches"] == 3
    assert grouped.counters["grouped_requests"] == 6
    assert seq.counters["prefill_dispatches"] == 6
    assert seq.counters["admit_dispatches"] == 6
    assert seq.counters["grouped_admissions"] == 0
    assert all(done_g[r].group_size == 2 for r in rid_g)


def test_shortest_bucket_first_under_load(setup):
    """With more arrived requests than free lanes, admission picks the
    shortest bucket present — a burst of short prompts is not starved
    behind a long head-of-queue arrival; FIFO order holds within a
    bucket and off load."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid_long = [loop.submit(_prompt(cfg, 60, seed=1), max_new=2),
                loop.submit(_prompt(cfg, 58, seed=2), max_new=2)]
    rid_short = [loop.submit(_prompt(cfg, 10, seed=3), max_new=2),
                 loop.submit(_prompt(cfg, 12, seed=4), max_new=2)]
    done = {s.rid: s for s in loop.run()}
    short_seq = [done[r].admit_seq for r in rid_short]
    long_seq = [done[r].admit_seq for r in rid_long]
    assert max(short_seq) < min(long_seq)     # shorts admitted first
    assert short_seq == sorted(short_seq)     # FIFO within the bucket
    assert long_seq == sorted(long_seq)
    assert all(done[r].bucket == 16 for r in rid_short)
    assert all(done[r].bucket == 64 for r in rid_long)


def test_shortest_bucket_aging_prevents_starvation(setup):
    """Sustained short-prompt overload must not starve a long request
    forever: after `max_head_skips` passed-over rounds the FIFO head's
    bucket is forced."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                     max_head_skips=2)
    rid_long = loop.submit(_prompt(cfg, 60, seed=1), max_new=2)
    rid_short = [loop.submit(_prompt(cfg, 10 + i % 3, seed=2 + i),
                             max_new=2) for i in range(12)]
    done = {s.rid: s for s in loop.run()}
    # head skipped at most max_head_skips rounds of <=2 admissions each,
    # then forced: the long prompt is admitted well before the shorts
    # drain (seq 0..12; without aging it would be seq 12)
    assert done[rid_long].admit_seq <= 2 * 2 + 1
    assert len(done[rid_long].tokens) == 2


def test_chunk_blocked_round_admits_short_and_keeps_aging(setup):
    """While a sliced prefill is in flight, a chunk-needing target must
    not idle the remaining free lanes: the round falls back to the
    shortest chunk-free bucket, and the blocked head's aging credit is
    left untouched (the starvation bound cannot be reset by a round that
    admits nothing for the head)."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                     chunk_prefill=16, max_head_skips=0)
    rid_l1 = loop.submit(_prompt(cfg, 57, seed=1), max_new=2)
    rid_l2 = loop.submit(_prompt(cfg, 60, seed=2), max_new=2)
    rid_s = loop.submit(_prompt(cfg, 10, seed=3), max_new=2)
    done = {s.rid: s for s in loop.run()}
    # the short rode a lane while long2 sat behind long1's sliced prefill
    assert done[rid_s].admit_seq < done[rid_l2].admit_seq
    for rid in (rid_l1, rid_l2, rid_s):
        assert len(done[rid].tokens) == 2
    assert done[rid_l1].prefill_chunks == 4      # ceil(57/16)
    assert done[rid_l2].prefill_chunks == 4      # ceil(60/16)
    assert loop._pending is None and not loop.active.any()


def test_first_token_sampling_seed_sensitivity(setup):
    """The admission dispatch must SAMPLE the first generated token when
    temperature > 0 (it used to argmax unconditionally): across seeds,
    a max_new=1 request yields more than one distinct token, and each
    seed is reproducible."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 24, seed=5)
    def first_tok(seed):
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=1,
                         temperature=2.0, sample_seed=seed)
        rid = loop.submit(prompt, max_new=1)
        return {s.rid: s.tokens for s in loop.run()}[rid]
    toks = [first_tok(s)[0] for s in range(6)]
    assert len(set(toks)) > 1          # not silently greedy
    assert first_tok(3) == [toks[3]]   # reproducible per seed


def test_grouped_admission_partial_free_lanes(setup):
    """A group larger than the free-lane count is split: the first
    len(free) members go in one dispatch, the rest wait for lanes."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rids = [loop.submit(_prompt(cfg, 20 + i, seed=i), max_new=2)
            for i in range(5)]                # all bucket 32
    done = {s.rid: s for s in loop.run()}
    assert [done[r].admit_seq for r in rids] == list(range(5))
    assert loop.counters["prefill_dispatches"] == 3    # 2 + 2 + 1
    assert loop.counters["grouped_requests"] == 4
    for r in rids:
        assert len(done[r].tokens) == 2


def test_chunked_prefill_admission(setup):
    """Sarathi-style sliced admission: same tokens as whole-bucket
    admission, prefill split into ceil(len/C) dispatches, decode lanes
    keep running while a long prompt prefills."""
    cfg, model, params = setup
    reqs = [(40, 4), (64, 6), (24, 3), (57, 5), (8, 2)]
    whole = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    sliced = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                       chunk_prefill=16)
    rid_w, rid_s = [], []
    for i, (t, mn) in enumerate(reqs):
        prompt = _prompt(cfg, t, seed=60 + i)
        rid_w.append(whole.submit(prompt, max_new=mn))
        rid_s.append(sliced.submit(prompt, max_new=mn))
    done_w = {s.rid: s for s in whole.run()}
    done_s = {s.rid: s for s in sliced.run()}
    import math
    for (t, mn), rw, rs in zip(reqs, rid_w, rid_s):
        assert done_s[rs].tokens == done_w[rw].tokens, (t, mn)
        expect_chunks = math.ceil(t / 16) if t > 16 else 1
        assert done_s[rs].prefill_chunks == expect_chunks
    assert not sliced.active.any() and sliced._pending is None


def test_chunked_prefill_bitwise_model_parity():
    """Model-level: a chunked prefill with C == attn_chunk reproduces the
    whole-bucket prefill bit-for-bit — logits and every cache field (the
    scan accumulates column sums in the same association order)."""
    import math
    cfg = reduced(get_config("granite-3-2b"))
    cfg = dataclasses.replace(cfg, attn_chunk=16)
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    t, bucket, C = 40, 64, 16
    prompt = _prompt(cfg, t, seed=9)
    padded = np.zeros(bucket, np.int64)
    padded[:t] = prompt
    lg_w, st_w = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(padded[None]),
                 "length": jnp.asarray([t])})
    ps = model.init_prefill_chunk_state(1, bucket)
    chunk = jax.jit(model.prefill_chunk)
    n_chunks = math.ceil(t / C)
    x_last = None
    for ci in range(n_chunks):
        x_last, ps = chunk(params, ps,
                           jnp.asarray(padded[None, ci * C:(ci + 1) * C]),
                           jnp.asarray(ci * C, jnp.int32),
                           jnp.asarray([t]))
    lg_c, st_c = jax.jit(model.prefill_finalize)(
        params, ps, x_last, jnp.asarray((n_chunks - 1) * C, jnp.int32),
        jnp.asarray([t]))
    np.testing.assert_array_equal(np.asarray(lg_c), np.asarray(lg_w))
    for a, b in zip(jax.tree.leaves(st_c), jax.tree.leaves(st_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recurrent_family_falls_back_to_exact_length():
    """ssm/hybrid/encdec can't mask right-padding out of their recurrent
    state: the default buckets=\"auto\" must silently downgrade to
    exact-length prefills instead of crashing at the first admit."""
    cfg = reduced(get_config("mamba2-1.3b"))
    from repro.core import baselines
    model = Model(cfg, baselines.dense(128))
    assert not model.supports_bucketed_prefill()
    params = model.init(jax.random.PRNGKey(0))
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                     chunk_prefill=8)           # both knobs must downgrade
    assert loop.buckets is None and loop.chunk_prefill == 0
    rid = loop.submit(_prompt(cfg, 24, seed=1), max_new=3)
    done = {s.rid: s for s in loop.run()}
    assert len(done[rid].tokens) == 3


def test_immediate_eos_empty_output_ttft(setup):
    """A request whose very FIRST generated token is EOS emits nothing;
    its ttft must anchor at completion, never go negative, and not poison
    the p50/p99 aggregates."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 24, seed=3)
    ref_loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    rid = ref_loop.submit(prompt, max_new=8)
    first = {s.rid: s.tokens for s in ref_loop.run()}[rid][0]
    loop = ServeLoop(model, params, lanes=2, eos=first, block=2)
    rid2 = loop.submit(prompt, max_new=8)
    st = {s.rid: s for s in loop.run()}[rid2]
    assert st.tokens == []
    assert st.t_admit <= st.t_first <= st.t_done
    assert st.ttft >= 0
    assert loop.aggregate()["p99_ttft_s"] >= 0


def test_chunked_prefill_ragged_bucket_uses_rounded_workspace(setup):
    """A bucket that is not a multiple of chunk_prefill (here: exact-length
    mode) must round the workspace up so every slice is full-width — one
    (C, ws) program, no silent ragged-tail compile — and still produce the
    whole-admission tokens."""
    cfg, model, params = setup
    prompt = _prompt(cfg, 57, seed=71)
    whole = ServeLoop(model, params, lanes=2, eos=-1, block=2, buckets=None)
    rw = whole.submit(prompt, max_new=4)
    sliced = ServeLoop(model, params, lanes=2, eos=-1, block=2,
                       buckets=None, chunk_prefill=16)
    rs = sliced.submit(prompt, max_new=4)
    out_w = {s.rid: s.tokens for s in whole.run()}[rw]
    done_s = {s.rid: s for s in sliced.run()}[rs]
    assert done_s.tokens == out_w
    assert done_s.prefill_chunks == 4          # ceil(57/16)
    assert ("chunk", 16, 64) in sliced._prefill_shapes


def test_runtime_eos_block_parity_and_shared_program(setup):
    """The lanes decode block is keyed on (steps, window) only: runtime
    per-lane eos must reproduce the statically-baked-eos scalar oracle
    bit for bit, engines with different eos ids must share ONE compiled
    block, and swapping the per-lane knob mix (greedy next to sampled
    lanes) must hit the SAME program — zero recompiles."""
    import functools
    from repro.launch.serve import (_lanes_block_fn, _model_key,
                                    decode_block_masked)
    cfg, model, params = setup
    prompts = np.stack([_prompt(cfg, 24, seed=s) for s in range(2)])
    logits, state0 = jax.jit(model.prefill)(params,
                                            {"tokens": jnp.asarray(prompts)})
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    active = jnp.ones(2, bool)
    rem = jnp.full(2, 8, jnp.int32)
    key = jax.random.PRNGKey(0)
    keys = jnp.broadcast_to(key, (2, 2))
    g_t = jnp.zeros(2, jnp.float32)            # all-greedy knob arrays
    g_k = jnp.zeros(2, jnp.int32)
    g_p = jnp.zeros(2, jnp.float32)

    def snap():
        # the block fn donates its carry on non-CPU backends — hand each
        # call its own copy so the test stays portable
        return (jax.tree.map(jnp.copy, state0), jnp.copy(tok0),
                jnp.copy(active), jnp.copy(rem), jnp.copy(keys))

    # greedy reference to learn a token id that actually appears
    fn = _lanes_block_fn(_model_key(model), 8)
    st, tk, ac, rm, ky = snap()
    *_, toks_ref, emit_ref = fn(params, st, tk, ac, rm,
                                jnp.full(2, -1, jnp.int32), ky,
                                g_t, g_k, g_p)
    eos = int(np.asarray(toks_ref)[3, 0])
    # statically-baked-eos scalar oracle (the pre-refactor block)
    static = jax.jit(functools.partial(decode_block_masked, model,
                                       eos=eos, steps=8))
    st, tk, ac, rm, ky = snap()
    *_, toks_s, emit_s = static(params, st, tk, ac, rm, key=jnp.copy(key))
    st, tk, ac, rm, ky = snap()
    *_, toks_r, emit_r = fn(params, st, tk, ac, rm,
                            jnp.full(2, eos, jnp.int32), ky, g_t, g_k, g_p)
    np.testing.assert_array_equal(np.asarray(toks_r), np.asarray(toks_s))
    np.testing.assert_array_equal(np.asarray(emit_r), np.asarray(emit_s))
    # every (eos, knob-mix) combination maps onto the same compiled program
    assert _lanes_block_fn(_model_key(model), 8) is fn
    before = fn._cache_size()
    st, tk, ac, rm, ky = snap()
    fn(params, st, tk, ac, rm, jnp.asarray([5, 7], jnp.int32), ky,
       jnp.asarray([0.0, 0.9], jnp.float32), jnp.asarray([0, 5], jnp.int32),
       jnp.asarray([0.0, 0.8], jnp.float32))
    assert fn._cache_size() == before          # knob mix: zero recompiles
    loop_a = ServeLoop(model, params, lanes=2, eos=5, block=8,
                       temperature=0.7, top_k=3)
    loop_b = ServeLoop(model, params, lanes=2, eos=7, block=8)
    fa = _lanes_block_fn(_model_key(loop_a.model), 8)
    fb = _lanes_block_fn(_model_key(loop_b.model), 8)
    assert fa is fb and fa is fn


def test_scanned_sampling_temperature_topk(setup):
    """temperature/top_k sampling in the scanned decode block: budgets
    are honoured, the stream is reproducible under a fixed seed, and the
    greedy default is unaffected."""
    cfg, model, params = setup
    def serve(temperature, top_k, seed=0):
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=4,
                         temperature=temperature, top_k=top_k,
                         sample_seed=seed)
        rids = [loop.submit(_prompt(cfg, 24, seed=11), max_new=6),
                loop.submit(_prompt(cfg, 30, seed=12), max_new=4)]
        done = {s.rid: s for s in loop.run()}
        return [done[r].tokens for r in rids]
    t1 = serve(1.0, 5)
    t2 = serve(1.0, 5)
    assert t1 == t2                            # same seed → same stream
    assert [len(t) for t in t1] == [6, 4]      # budgets honoured
    assert serve(1.0, 5, seed=9) != t1         # a new seed moves the stream
    greedy = serve(0.0, 0)
    ref_loop = ServeLoop(model, params, lanes=2, eos=-1, block=4)
    r1 = ref_loop.submit(_prompt(cfg, 24, seed=11), max_new=6)
    r2 = ref_loop.submit(_prompt(cfg, 30, seed=12), max_new=4)
    done = {s.rid: s.tokens for s in ref_loop.run()}
    assert greedy == [done[r1], done[r2]]
    # top_k=1 with any temperature degenerates to greedy
    assert serve(0.7, 1) == greedy


def test_backdated_submit_preserves_global_fifo(setup):
    """A submit whose arrival predates requests ALREADY drained into the
    per-bucket deques must still take its arrival-rank place (the global
    FIFO head / aging bound protect the true oldest request), while an
    equal-arrival submit keeps FIFO-among-ties (after the drained one)."""
    import time as _time
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=2)
    ra = loop.submit(_prompt(cfg, 10, seed=1), max_new=2, arrival=0.0)
    loop._t0 = _time.monotonic()
    loop._drain_arrivals(loop._now())          # A is now in its deque
    rb = loop.submit(_prompt(cfg, 12, seed=2), max_new=2, arrival=0.0)
    rc = loop.submit(_prompt(cfg, 11, seed=3), max_new=2, arrival=-1.0)
    assert [r.rid for r in loop.queue] == [rc, ra, rb]  # arrival order
    done = {s.rid: s for s in loop.run()}
    assert (done[rc].admit_seq < done[ra].admit_seq
            < done[rb].admit_seq)


def test_serve_window_arg_validated(setup):
    """window must be 'auto' or None — anything else (a typo, an int)
    would silently disable windowing, so it is rejected up front."""
    cfg, model, params = setup
    with pytest.raises(AssertionError):
        ServeLoop(model, params, lanes=1, window=256)
    with pytest.raises(AssertionError):
        ServeLoop(model, params, lanes=1, window="Auto")


def test_scanned_sampling_top_p(setup):
    """top-p (nucleus) sampling in the scanned decode block + admission
    seed: keyed like temperature/top_k, reproducible per seed, a
    vanishing nucleus degenerates to greedy, and top_p=0 (disabled) is
    exactly the plain-sampling stream."""
    cfg, model, params = setup

    def serve(temperature, top_p, seed=0, top_k=0):
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=4,
                         temperature=temperature, top_k=top_k,
                         top_p=top_p, sample_seed=seed)
        rids = [loop.submit(_prompt(cfg, 24, seed=21), max_new=6),
                loop.submit(_prompt(cfg, 30, seed=22), max_new=4)]
        done = {s.rid: s for s in loop.run()}
        return [done[r].tokens for r in rids]

    t1 = serve(1.0, 0.8)
    assert t1 == serve(1.0, 0.8)               # same seed → same stream
    assert [len(t) for t in t1] == [6, 4]      # budgets honoured
    assert serve(1.0, 0.8, seed=9) != t1       # a new seed moves it
    greedy = serve(0.0, 0.0)
    # nucleus of vanishing mass keeps only the argmax token per step
    assert serve(0.9, 1e-6) == greedy
    # top_p outside (0, 1) disables truncation entirely: 0.0 and 1.0
    # draw the identical (untruncated) stream from the same seed
    assert serve(1.0, 0.0) == serve(1.0, 1.0)
    # composes with top_k (top_k truncates first)
    tk = serve(1.0, 0.9, top_k=5)
    assert tk == serve(1.0, 0.9, top_k=5)


def test_greedy_generate_sampling_default_key(setup):
    """temperature > 0 with the default key=None must sample, not crash
    (jax.random.split(None) regression)."""
    from repro.launch.serve import greedy_generate
    cfg, model, params = setup
    batch = {"tokens": jnp.asarray(_prompt(cfg, 16, seed=1)[None])}
    toks, _ = greedy_generate(model, params, batch, steps=4,
                              temperature=1.0)
    assert toks.shape == (1, 4)
    # and an explicit key is reproducible
    t1, _ = greedy_generate(model, params, batch, steps=4, temperature=1.0,
                            key=jax.random.PRNGKey(7))
    t2, _ = greedy_generate(model, params, batch, steps=4, temperature=1.0,
                            key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_metrics_sanity(setup):
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    for i, (t, mn) in enumerate(((24, 4), (32, 6), (24, 3), (24, 0))):
        loop.submit(_prompt(cfg, t, seed=20 + i), max_new=mn)
    done = loop.run()
    agg = loop.aggregate()
    assert agg["requests"] == 4
    assert agg["tokens"] == sum(len(s.tokens) for s in done) == 13
    assert agg["tokens_per_s"] > 0
    assert agg["wall_s"] > 0
    assert 0 < agg["mean_occupancy"] <= 1
    assert 0 <= agg["p50_ttft_s"] <= agg["p99_ttft_s"]
    assert agg["prefill_programs"] >= 1
    for s in done:
        assert len(s.tokens) == s.max_new    # incl. the prefill-only one
        assert 0 <= s.t_admit <= s.t_done
        assert s.latency > 0
        assert 0 < s.occupancy <= 1
        if s.tokens:
            assert s.t_admit <= s.t_first <= s.t_done
            assert s.decode_tps > 0
    # a prefill-only request as the ONLY work must complete, not crash
    solo = ServeLoop(model, params, lanes=2, eos=-1, block=2)
    solo.submit(_prompt(cfg, 24, seed=30), max_new=0)
    only = solo.run()
    assert len(only) == 1 and only[0].tokens == []
    assert not solo.active.any() and not solo.queue
