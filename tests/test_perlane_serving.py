"""Per-lane serving knobs, priority preemption, and drain-aware
reservation.

Covers the PR-8 scheduler/block refactor end to end: mixed per-request
`SamplingParams` decoded in ONE scanned block (greedy lanes bitwise vs a
solo run, seeded-sampled lanes stream-identical), preempt/resume
token-identity for greedy AND pinned-seed requests, priority-class
admission ordering, the reservation fast path (bitwise-neutral, counted),
per-request stop tokens, and the one-compiled-program guarantee across
arbitrary knob mixes (`counters["decode_block_programs"]`).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import Request, SamplingParams, ServeLoop
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-3-2b"))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


def _solo(model, params, req_kw, **loop_kw):
    """Reference: the same request served alone on a fresh engine."""
    loop = ServeLoop(model, params, lanes=1, block=4, **loop_kw)
    h = loop.submit(Request(**req_kw))
    loop.run()
    return h.tokens


# -- mixed per-lane knobs in one block ---------------------------------------


def test_mixed_knob_block_matches_solo(setup):
    """Greedy, seeded-sampled, and top-k/top-p lanes decoding TOGETHER in
    one scanned block each reproduce their solo-engine stream exactly —
    the greedy lane bitwise, the seeded lanes because a lane's sampled
    stream is a function of (seed, tokens generated) alone. And the whole
    mix runs on ONE compiled block program."""
    cfg, model, params = setup
    reqs = [
        dict(prompt=_prompt(cfg, 16, 1), max_new=8),                  # greedy
        dict(prompt=_prompt(cfg, 20, 2), max_new=8,
             sampling=SamplingParams(temperature=0.9, top_k=5),
             sample_seed=7),
        dict(prompt=_prompt(cfg, 24, 3), max_new=6,
             sampling=SamplingParams(temperature=1.0, top_p=0.8),
             sample_seed=11),
    ]
    loop = ServeLoop(model, params, lanes=3, eos=-1, block=4)
    hs = [loop.submit(Request(**kw)) for kw in reqs]
    loop.run()
    assert loop.counters["decode_block_programs"] == 1
    for h, kw in zip(hs, reqs):
        assert h.tokens == _solo(model, params, kw, eos=-1)
        assert len(h.tokens) == kw["max_new"]


def test_all_greedy_engine_keys_untouched(setup):
    """An all-greedy engine must not consume RNG: the per-lane key
    carries pass through the block bitwise-unchanged (the sampled branch
    is gated out by `lax.cond`), so greedy serving stays deterministic
    and bitwise-reproducible run to run."""
    cfg, model, params = setup

    def serve():
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=4)
        hs = [loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=8))
              for s in (1, 2)]
        keys0 = loop._lane_keys.copy()
        loop.run()
        np.testing.assert_array_equal(loop._lane_keys, keys0)
        return [h.tokens for h in hs]

    assert serve() == serve()


def test_perlane_eos_via_sampling_params(setup):
    """`SamplingParams(eos=...)` stops ONE lane on its own token id while
    its neighbor (engine default eos=-1) runs out its full budget."""
    cfg, model, params = setup
    probe = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    hp = probe.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=8))
    probe.run()
    stop = hp.tokens[3]                        # a token the stream emits
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=4)
    h_stop = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=8,
                                 sampling=SamplingParams(eos=stop)))
    h_full = loop.submit(Request(prompt=_prompt(cfg, 16, 2), max_new=8))
    loop.run()
    assert h_stop.tokens == hp.tokens[:3]      # eos is a stop, not an output
    assert len(h_full.tokens) == 8             # neighbor lane unaffected


# -- priority classes + preemption -------------------------------------------


def test_priority_admits_first(setup):
    """With one lane and two waiting classes, the higher class admits
    first even though the low-priority request arrived earlier."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    h_lo = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=4,
                               arrival=0.0, priority=0))
    h_hi = loop.submit(Request(prompt=_prompt(cfg, 16, 2), max_new=4,
                               arrival=0.0, priority=3))
    loop.run()
    assert h_hi.stats.admit_seq < h_lo.stats.admit_seq
    assert loop.counters["preemptions"] == 0   # a free lane never preempts


def _preempt_run(model, params, victim_kw, cfg):
    """Serve victim + filler on 2 lanes, inject a priority-5 arrival
    mid-decode, and return (victim tokens, loop)."""
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=4)
    h_v = loop.submit(Request(**victim_kw))
    loop.submit(Request(prompt=_prompt(cfg, 16, 90), max_new=12,
                        priority=1))
    loop.schedule()                            # both admitted, lanes full
    loop._step_block()                         # one block into decode
    loop.submit(Request(prompt=_prompt(cfg, 16, 91), max_new=4, priority=5))
    loop.run()
    return h_v, loop


def test_preempt_resume_greedy_token_identical(setup):
    """A high-priority arrival with no free lane evicts the LOWEST
    priority active lane; the victim requeues and resumes with exactly
    the tokens an uninterrupted run produces."""
    cfg, model, params = setup
    victim = dict(prompt=_prompt(cfg, 16, 1), max_new=12, priority=0)
    h_v, loop = _preempt_run(model, params, victim, cfg)
    assert loop.counters["preemptions"] == 1
    assert h_v.stats.preemptions == 1          # priority 0 < filler's 1
    assert h_v.tokens == _solo(model, params, victim, eos=-1)
    assert len(h_v.tokens) == 12


def test_preempt_resume_seeded_sampled_token_identical(setup):
    """The per-lane PRNG carry is captured and restored across the
    preempt/resume boundary, so even a SAMPLED (pinned-seed) victim
    resumes stream-identically — the key splits once per generated
    token, wherever and whenever those tokens run."""
    cfg, model, params = setup
    victim = dict(prompt=_prompt(cfg, 16, 1), max_new=12, priority=0,
                  sampling=SamplingParams(temperature=0.9, top_k=8),
                  sample_seed=13)
    h_v, loop = _preempt_run(model, params, victim, cfg)
    assert loop.counters["preemptions"] == 1
    assert h_v.stats.preemptions == 1
    assert h_v.tokens == _solo(model, params, victim, eos=-1)


def test_equal_priority_never_preempts(setup):
    """Same-class congestion waits for a lane like PR-4 did — preemption
    requires a STRICTLY higher class."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=4)
    for s in range(2):
        loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=8,
                            priority=2))
    loop.schedule()
    loop._step_block()
    loop.submit(Request(prompt=_prompt(cfg, 16, 9), max_new=4, priority=2))
    loop.run()
    assert loop.counters["preemptions"] == 0
    assert len(loop.completed) == 3


# -- drain-aware reservation --------------------------------------------------


def test_reservation_counts_and_is_bitwise_neutral(setup):
    """With every lane busy the scheduler pre-pops soon-to-fit requests
    (reservations > 0, each later admitted as reserved_admits); the
    resulting greedy token streams are identical to a reservation-free
    engine — it is purely an admission-latency optimization."""
    cfg, model, params = setup

    def serve(reserve_blocks):
        loop = ServeLoop(model, params, lanes=2, eos=-1, block=4,
                         reserve_blocks=reserve_blocks)
        hs = [loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=8))
              for s in range(2)]
        loop.schedule()                        # saturate both lanes
        hs += [loop.submit(Request(prompt=_prompt(cfg, 16, 10 + s),
                                   max_new=8)) for s in range(3)]
        loop.run()
        return [h.tokens for h in hs], loop

    toks_res, loop_res = serve(reserve_blocks=8)
    toks_off, loop_off = serve(reserve_blocks=0)
    assert toks_res == toks_off
    assert loop_res.counters["reservations"] > 0
    assert (loop_res.counters["reserved_admits"]
            == loop_res.counters["reservations"])
    assert loop_off.counters["reservations"] == 0
    assert loop_off.counters["reserved_admits"] == 0


def test_predicted_free_blocks_uses_eos_stats(setup):
    """Once EOS terminations dominate completed traffic, the drain
    prediction bounds a lane's remaining work by the observed mean EOS
    length instead of its worst-case budget."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=100))
    loop.schedule()
    assert loop.predicted_free_blocks() == {0: 25}   # 100 rem / block 4
    loop._eos_lens = [4, 4, 4, 4]              # observed EOS lengths
    assert loop.predicted_free_blocks() == {0: 1}    # bounded by the mean
    loop._budget_done = 5                      # budget exhaustion dominates
    assert loop.predicted_free_blocks() == {0: 25}


def test_predicted_free_blocks_class_local(setup):
    """Drain prediction is class-local first: a (priority, bucket) cell
    with >= 4 EOS samples overrides the global mean — short bursty and
    long bulk traffic stop polluting each other's forecasts — and below
    the cell's sample floor the global mean applies unchanged."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=100,
                        priority=2))
    loop.schedule()
    st = loop.stats[loop._lane_rid[0]]
    loop._eos_lens = [40, 40, 40, 40]          # global mean 40 → 10 blocks
    loop._eos_by_class[(st.priority, st.bucket)] = [4, 4, 4]
    assert loop.predicted_free_blocks() == {0: 10}   # below the cell floor
    loop._eos_by_class[(st.priority, st.bucket)].append(4)
    assert loop.predicted_free_blocks() == {0: 1}    # class mean 4 → 1
    # another class's samples never leak into this lane's forecast
    loop._eos_by_class[(0, st.bucket)] = [80, 80, 80, 80]
    assert loop.predicted_free_blocks() == {0: 1}
