"""In-place donated decode tests.

Three coordinated guarantees under test:

  * bitwise parity — the read-window/storage-write split
    (`decode_attention_stacked`, `Model.decode_step(inplace=True)`) must
    match the functional path bit for bit across kv dtypes, policies,
    select modes, the fused engine, lane masks, and windowed vs
    full-width dispatch;
  * the in-place guarantee itself — the compiled decode block's
    temp-allocation bytes must stay FLAT as `slots` grows (a per-step
    carry copy scales with slots and resurrects the copy floor this PR
    kills), and `donate_argnums` must surface as input-output aliasing
    in the lowered block programs;
  * the additive chunk window grid — `decode_window(grid=c)` quantizes
    window widths to multiples of c with a bounded program count.
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_config, reduced
from repro.core import baselines
from repro.core.attention import decode_attention, decode_attention_stacked
from repro.core.cache import decode_window
from repro.launch import serve
from repro.models.transformer import Model
from repro.surgery import state_lane_select
from tests.test_windowed_decode import _assert_trees_equal, _filled_cache

jax.config.update("jax_platform_name", "cpu")

B, HK, HQ, D = 2, 2, 4, 16


def _stack(cache, layers=1):
    """Layer-stack a single-layer cache (the DecodeState kv layout)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (layers,) + a.shape), cache)


def _qkv(i, key=100):
    ks = jax.random.split(jax.random.PRNGKey(key + i), 3)
    return (jax.random.normal(ks[0], (B, HQ, D)),
            jax.random.normal(ks[1], (B, HK, D)),
            jax.random.normal(ks[2], (B, HK, D)))


# -- additive chunk window grid ----------------------------------------------


def test_decode_window_chunk_grid():
    prune = PruneConfig(policy="unicaim", heavy_budget=4032, reserve=64,
                        select_k=64, sink_tokens=2, recent_window=8)
    # need = fill + steps, rounded UP to a multiple of c
    assert decode_window(100, 28, 4096, prune, grid=64) == 128
    assert decode_window(129, 1, 4096, prune, grid=64) == 192
    assert decode_window(128, 1, 4096, prune, grid=512) == 512
    # tighter than pow2 between powers of two
    assert decode_window(1025, 1, 4096, prune) == 2048       # pow2 doubles
    assert decode_window(1025, 1, 4096, prune, grid=256) == 1280
    # select_k floor and full-width fallback hold on every grid
    assert decode_window(0, 1, 4096, prune, grid=16) == 64
    assert decode_window(4090, 8, 4096, prune, grid=64) is None
    # select_blocks must partition the chunked window too
    nb3 = dataclasses.replace(prune, select_blocks=3, select_k=63)
    assert decode_window(10, 1, 4096, nb3, grid=64) is None
    nb2 = dataclasses.replace(prune, select_blocks=2)
    assert decode_window(100, 28, 4096, nb2, grid=64) == 128
    # program-count bound: every reachable width is one of slots/c values
    widths = {decode_window(f, 4, 4096, prune, grid=256)
              for f in range(0, 4096, 7)}
    assert len(widths) <= 4096 // 256 + 1                    # + the None


# -- core step: bitwise parity ------------------------------------------------


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("policy,select_mode,fused", [
    ("unicaim", "topk", False),
    ("unicaim", "topk", True),
    ("unicaim", "threshold", False),
    ("h2o", "topk", False),
    ("dense", "topk", False),
])
@pytest.mark.parametrize("windowed", [False, True])
def test_inplace_step_bitwise_parity(kv_dtype, policy, select_mode, fused,
                                     windowed):
    """`decode_attention_stacked` == functional `decode_attention`, bit
    for bit: outputs and every cache field, across multiple steps."""
    if policy != "unicaim" and kv_dtype == "int8":
        pytest.skip("int8 KV is a unicaim-mode knob")
    prune = PruneConfig(policy=policy, heavy_budget=48, reserve=16,
                        sink_tokens=2, recent_window=4, select_k=8,
                        select_mode=select_mode, kv_dtype=kv_dtype,
                        fused=fused, fused_backend="xla",
                        accumulate="exact" if policy == "h2o" else "approx")
    fills = [3, 9]
    cf = _filled_cache(fills, prune.slots, prune, dtype=jnp.bfloat16,
                       key=sum(fills))
    kv = _stack(cf)
    w = decode_window(max(fills), 3, prune.slots, prune) if windowed else None
    if windowed:
        assert w is not None and w < prune.slots
    step_i = jax.jit(lambda c, q, k, v: decode_attention_stacked(
        c, 0, q, k, v, prune, w, None))
    step_f = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))
    for i in range(3):
        q, kn, vn = _qkv(i)
        kv, oi = step_i(kv, q, kn, vn)
        cf, of = step_f(cf, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(oi), np.asarray(of))
        _assert_trees_equal(kv, _stack(cf))


def test_inplace_eviction_parity():
    """Full lanes (window=None): argmin eviction + overwrite stay
    bit-identical through the scatter write path."""
    prune = baselines.unicaim(heavy=24, reserve=8, select_k=8,
                              sink_tokens=2, recent_window=4)
    slots = prune.slots
    cf = _filled_cache([slots, slots - 1], slots, prune,
                       dtype=jnp.float32, key=7)
    kv = _stack(cf)
    step_i = jax.jit(lambda c, q, k, v: decode_attention_stacked(
        c, 0, q, k, v, prune, None, None))
    step_f = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))
    for i in range(4):                       # crosses full → evicts
        q, kn, vn = _qkv(i, key=7)
        kv, oi = step_i(kv, q, kn, vn)
        cf, of = step_f(cf, q, kn, vn)
        np.testing.assert_array_equal(np.asarray(oi), np.asarray(of))
        _assert_trees_equal(kv, _stack(cf))
    assert int(np.asarray(kv.fill).max()) == slots


def test_inplace_active_mask_freezes_lanes():
    """`active` gates writes at the source (dropped scatters): inactive
    lanes' cache rows stay frozen while active lanes march in lockstep
    with the functional path."""
    prune = baselines.unicaim(heavy=24, reserve=8, select_k=8,
                              sink_tokens=2, recent_window=4)
    fills = [5, 12]
    cf = _filled_cache(fills, prune.slots, prune, dtype=jnp.float32, key=3)
    kv = _stack(cf)
    active = jnp.asarray([True, False])
    w = decode_window(max(fills), 3, prune.slots, prune)
    step_i = jax.jit(lambda c, q, k, v: decode_attention_stacked(
        c, 0, q, k, v, prune, w, active))
    step_f = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))
    frozen = jax.tree.map(lambda a: np.asarray(a[:, 1]), _stack(cf))
    for i in range(3):
        q, kn, vn = _qkv(i, key=40)
        kv, oi = step_i(kv, q, kn, vn)
        cf, of = step_f(cf, q, kn, vn)
        # active lane: output + every cache field match the functional step
        np.testing.assert_array_equal(np.asarray(oi)[0], np.asarray(of)[0])
        _assert_trees_equal(jax.tree.map(lambda a: a[:, 0], kv),
                            jax.tree.map(lambda a: a[:, 0], _stack(cf)))
        # inactive lane: bit-frozen at its pre-mask state
        _assert_trees_equal(jax.tree.map(lambda a: a[:, 1], kv), frozen)


# -- model + masked block parity ---------------------------------------------


def _tiny_model(kv_dtype="bf16"):
    cfg = reduced(get_config("longchat-7b"))
    prune = dataclasses.replace(
        baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8),
        kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
             "length": jnp.asarray([9, 26], jnp.int32)}
    logits, state = jax.jit(model.prefill)(params, batch)
    return model, params, state, jnp.argmax(logits, -1)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("window", [None, 64])
def test_model_inplace_decode_step_parity(kv_dtype, window):
    """decode_step(inplace=True) — layer scan over the stacked cache with
    scatter writes — is bitwise the functional slice/merge step: logits
    and every DecodeState leaf."""
    model, params, state, tok = _tiny_model(kv_dtype)
    assert model.supports_inplace_decode()
    si, sf = state, state
    ti, tf = tok, tok
    step = jax.jit(model.decode_step,
                   static_argnames=("window", "inplace"))
    for _ in range(4):
        li, si = step(params, si, ti, window=window, inplace=True)
        lf, sf = step(params, sf, tf, window=window, inplace=False)
        np.testing.assert_array_equal(np.asarray(li), np.asarray(lf))
        ti, tf = jnp.argmax(li, -1), jnp.argmax(lf, -1)
    _assert_trees_equal(si, sf)


def test_masked_block_inplace_parity():
    """The masked decode block's in-place lane gating (dropped scatters)
    matches functional step + `state_lane_select` exactly — tokens,
    lane masks, and every state leaf."""
    model, params, state, tok = _tiny_model()
    active = jnp.asarray([True, False])
    rem = jnp.asarray([6, 0], jnp.int32)
    eos = jnp.int32(-1)
    key = jax.random.PRNGKey(0)

    fn = jax.jit(lambda st, tk, a, r: serve.decode_block_masked(
        model, params, st, tk, a, r, eos, key, steps=3, window=64))
    si, ti, ai, ri, _, toks_i, em_i = fn(state, tok, active, rem)

    # functional oracle: the same loop with inplace=False steps and the
    # full-width state_lane_select merge the old block used
    sf, tf, af, rf = state, tok, active, rem
    toks_f, em_f = [], []
    for _ in range(3):
        lf, s_new = model.decode_step(params, sf, tf, inplace=False,
                                      window=64)
        sf = state_lane_select(af, s_new, sf)
        live = af & (rf > 0)
        em = live & (tf != eos)
        toks_f.append(np.asarray(tf))
        em_f.append(np.asarray(em))
        rf = rf - em.astype(rf.dtype)
        af = em & (rf > 0)
        tf = jnp.argmax(lf, -1).astype(tf.dtype)
    np.testing.assert_array_equal(np.asarray(toks_i), np.stack(toks_f))
    np.testing.assert_array_equal(np.asarray(em_i), np.stack(em_f))
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(af))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(tf))
    _assert_trees_equal(si, sf)


# -- MLA latent-cache in-place path -------------------------------------------


def _tiny_mla_model(kv_dtype="bf16"):
    cfg = reduced(get_config("deepseek-v3-671b"))
    prune = dataclasses.replace(
        baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8),
        kv_dtype=kv_dtype)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32))),
             "length": jnp.asarray([9, 26], jnp.int32)}
    logits, state = jax.jit(model.prefill)(params, batch)
    return model, params, state, jnp.argmax(logits, -1)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("window", [None, 32])
def test_mla_inplace_decode_step_parity(kv_dtype, window):
    """mla_moe rides the zero-copy path now: `mla_decode_stacked` over
    the layer-stacked LATENT cache (two segments scanned sequentially
    with a running global layer offset) is bitwise the functional
    `mla_decode` step — logits and every DecodeState leaf, bf16 and
    quantized latents, windowed and full-width."""
    model, params, state, tok = _tiny_mla_model(kv_dtype)
    assert model.supports_inplace_decode()
    si, sf = state, state
    ti, tf = tok, tok
    step = jax.jit(model.decode_step,
                   static_argnames=("window", "inplace"))
    for _ in range(4):
        li, si = step(params, si, ti, window=window, inplace=True)
        lf, sf = step(params, sf, tf, window=window, inplace=False)
        np.testing.assert_array_equal(np.asarray(li), np.asarray(lf))
        ti, tf = jnp.argmax(li, -1), jnp.argmax(lf, -1)
    _assert_trees_equal(si, sf)


def test_mla_masked_block_inplace_parity():
    """The serving block's in-place lane gating works on the latent
    cache too: dropped scatters freeze finished MLA lanes exactly like
    functional step + `state_lane_select`."""
    model, params, state, tok = _tiny_mla_model()
    active = jnp.asarray([True, False])
    rem = jnp.asarray([6, 0], jnp.int32)
    eos = jnp.int32(-1)
    key = jax.random.PRNGKey(0)

    fn = jax.jit(lambda st, tk, a, r: serve.decode_block_masked(
        model, params, st, tk, a, r, eos, key, steps=3, window=None))
    si, ti, ai, ri, _, toks_i, em_i = fn(state, tok, active, rem)

    sf, tf, af, rf = state, tok, active, rem
    toks_f, em_f = [], []
    for _ in range(3):
        lf, s_new = model.decode_step(params, sf, tf, inplace=False)
        sf = state_lane_select(af, s_new, sf)
        live = af & (rf > 0)
        em = live & (tf != eos)
        toks_f.append(np.asarray(tf))
        em_f.append(np.asarray(em))
        rf = rf - em.astype(rf.dtype)
        af = em & (rf > 0)
        tf = jnp.argmax(lf, -1).astype(tf.dtype)
    np.testing.assert_array_equal(np.asarray(toks_i), np.stack(toks_f))
    np.testing.assert_array_equal(np.asarray(em_i), np.stack(em_f))
    _assert_trees_equal(si, sf)


def test_mla_lanes_block_donation_surfaces_as_aliasing():
    """Donation must surface as input→output aliasing for the MLA
    stacked latent cache exactly as for the GQA cache — the zero-valued
    dep pin in `mla_decode_stacked` keeps the scan carry aliased."""
    model, params, state, tok = _tiny_mla_model()
    fn = lambda p, st, tk, a, r, e, k, t, tk_, tp: \
        serve.decode_block_lanes(model, p, st, tk, a, r, e, k, t, tk_,
                                 tp, steps=3, window=None)
    args = (params, state, tok, jnp.ones((B,), bool),
            jnp.full((B,), 8, jnp.int32), jnp.full((B,), -1, jnp.int32),
            jnp.broadcast_to(jax.random.PRNGKey(0), (B, 2)),
            jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.float32))
    lowered = jax.jit(fn, donate_argnums=(1, 2, 3, 4, 6)).lower(*args)
    text = lowered.as_text()
    n_state_leaves = len(jax.tree.leaves(state))
    aliased = len(re.findall(r"tf\.aliasing_output", text))
    assert aliased >= n_state_leaves + 1, (
        f"only {aliased} aliased args for {n_state_leaves} state leaves")


# -- the in-place guarantee: aliasing + flat temp bytes -----------------------


def _compiled_block(slots, steps=4, donate=False, masked=True, lanes=False):
    cfg = reduced(get_config("longchat-7b"))
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune, decode_slots=slots)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(B)
    tok = jnp.zeros((B,), jnp.int32)
    w = decode_window(48, steps, slots, prune)
    if lanes:
        fn = lambda p, st, tk, a, r, e, k, t, tk_, tp: \
            serve.decode_block_lanes(model, p, st, tk, a, r, e, k, t, tk_,
                                     tp, steps=steps, window=w)
        args = (params, state, tok, jnp.ones((B,), bool),
                jnp.full((B,), 8, jnp.int32), jnp.full((B,), -1, jnp.int32),
                jnp.broadcast_to(jax.random.PRNGKey(0), (B, 2)),
                jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
                jnp.zeros((B,), jnp.float32))
        donate_argnums = (1, 2, 3, 4, 6) if donate else ()
    elif masked:
        fn = lambda p, st, tk, a, r, e, k: serve.decode_block_masked(
            model, p, st, tk, a, r, e, k, steps=steps, window=w)
        args = (params, state, tok, jnp.ones((B,), bool),
                jnp.full((B,), 8, jnp.int32), jnp.int32(-1),
                jax.random.PRNGKey(0))
        donate_argnums = (1, 2, 3, 4, 6) if donate else ()
    else:
        fn = lambda p, st, tk: serve.decode_block(model, p, st, tk,
                                                  steps=steps, window=w)
        args = (params, state, tok)
        donate_argnums = (1, 2) if donate else ()
    lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    return lowered, len(jax.tree.leaves(state))


@pytest.mark.parametrize("masked", [False, True])
def test_block_fn_donation_surfaces_as_aliasing(masked):
    """With donation forced on (the serve path enables it off-CPU), every
    DecodeState buffer must alias input→output in the lowered block —
    the windowed path no longer breaks aliasing the way the old
    slot_window copy/merge did."""
    lowered, n_state_leaves = _compiled_block(512, donate=True,
                                              masked=masked)
    text = lowered.as_text()
    aliased = len(re.findall(r"tf\.aliasing_output", text))
    # state leaves + tok (+ active/rem/key on the masked block)
    assert aliased >= n_state_leaves + 1, (
        f"only {aliased} aliased args for {n_state_leaves} state leaves")


def test_masked_block_temp_bytes_flat_in_slots():
    """Compiled temp allocation must NOT scale with the slot count: a
    per-step O(slots) carry copy inside the decode scan is exactly the
    copy floor this path exists to kill (the windowed program reads
    [:W], so slots only contribute aliased in/out buffers)."""
    temps = {}
    for slots in (512, 4096):
        lowered, _ = _compiled_block(slots)
        ma = lowered.compile().memory_analysis()
        temps[slots] = ma.temp_size_in_bytes
    assert temps[4096] <= temps[512] * 1.10 + (64 << 10), (
        f"temp bytes scale with slots: {temps} — the decode block is "
        f"copying the cache carry again")


def test_lanes_block_donation_surfaces_as_aliasing():
    """The per-lane-knob block (`decode_block_lanes`, what ServeLoop
    actually dispatches) must keep every DecodeState buffer aliased
    input→output under donation — threading [lanes]-shaped knob/key
    arrays through the scan carry must not break the zero-copy path."""
    lowered, n_state_leaves = _compiled_block(512, donate=True, lanes=True)
    text = lowered.as_text()
    aliased = len(re.findall(r"tf\.aliasing_output", text))
    # state leaves + tok + active/rem/keys
    assert aliased >= n_state_leaves + 1, (
        f"only {aliased} aliased args for {n_state_leaves} state leaves")


def test_lanes_block_temp_bytes_flat_in_slots():
    """Same flat-temp guarantee for the per-lane-knob block: the
    vectorized sampler works over [lanes, vocab] logits — independent of
    the slot count — so temp bytes must stay flat in `slots` exactly
    like the scalar block."""
    temps = {}
    for slots in (512, 4096):
        lowered, _ = _compiled_block(slots, lanes=True)
        ma = lowered.compile().memory_analysis()
        temps[slots] = ma.temp_size_in_bytes
    assert temps[4096] <= temps[512] * 1.10 + (64 << 10), (
        f"temp bytes scale with slots: {temps} — the lanes decode block "
        f"is copying the cache carry again")
