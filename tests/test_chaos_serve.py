"""Fault-tolerant serving under injected chaos.

Covers the PR-10 fault-tolerance subsystem end to end against the
deterministic `runtime.chaos` harness: NaN quarantine + retry replays
greedy AND seeded-sampled streams token-identically, deadlines and
cancellation free lanes within one decode block through the in-device
active mask, the degradation ladder steps down/up with bitwise-unchanged
healthy lanes, bounded admission sheds/rejects deterministically by
priority, and the structured-rejection valve closes the silent-hang
holes (max_new=0, prompt over every bucket) — while an inert engine
stays bitwise-identical to the pre-chaos one (one compiled block
program, zero new counters).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import Request, SamplingParams, ServeLoop
from repro.models.transformer import Model
from repro.runtime.chaos import ChaosConfig, flood

jax.config.update("jax_platform_name", "cpu")

# recent_window differs from the other serving test modules on purpose:
# block fns are memoized by (cfg, prune, ...) VALUE, so a distinct prune
# config gives this module its own jit cache — the program-count
# assertions here and in test_perlane_serving can't see each other's
# compiled entries regardless of pytest collection order.
PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=12)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-3-2b"))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


def _mixed_reqs(cfg):
    """Greedy + seeded-sampled request set shared by the replay tests."""
    return [
        dict(prompt=_prompt(cfg, 16, 1), max_new=8),                  # greedy
        dict(prompt=_prompt(cfg, 20, 2), max_new=8,
             sampling=SamplingParams(temperature=0.9, top_k=5),
             sample_seed=7),
        dict(prompt=_prompt(cfg, 24, 3), max_new=8,
             sampling=SamplingParams(temperature=1.0, top_p=0.8),
             sample_seed=11),
    ]


def _serve(model, params, req_kws, **loop_kw):
    loop = ServeLoop(model, params, eos=-1, block=4, **loop_kw)
    hs = [loop.submit(Request(**kw)) for kw in req_kws]
    loop.run()
    return loop, hs


# -- quarantine + retry -------------------------------------------------------


def test_quarantine_retry_replays_token_identically(setup):
    """A NaN-poisoned lane is quarantined and its request deterministically
    retried from scratch: greedy lanes replay bitwise, seeded-sampled
    lanes replay because the stream is f(seed, tokens generated) alone —
    so EVERY affected request still completes with the clean run's exact
    token stream."""
    cfg, model, params = setup
    reqs = _mixed_reqs(cfg)
    _, clean = _serve(model, params, reqs, lanes=3)

    chaos = ChaosConfig(seed=3, logit_fault_rate=1.0,
                        fault_blocks=(1,), fault_lanes=(0, 1))
    loop, hs = _serve(model, params, reqs, lanes=3, chaos=chaos)
    assert loop.counters["quarantined_lanes"] >= 2
    assert loop.counters["retried_requests"] >= 2
    for h, ref in zip(hs, clean):
        assert h.outcome == "done"
        assert h.tokens == ref.tokens
    retried = [h for h in hs if h.stats.retries]
    assert len(retried) >= 2
    assert any(h.stats.retries for h in hs[1:]), "a sampled lane retried"


def test_quarantine_exhausted_retries_fails_structurally(setup):
    """Every block poisons the lane → retries exhaust and the request
    resolves to outcome "failed" instead of wedging the lane (the engine
    keeps serving: a healthy lane completes untouched)."""
    cfg, model, params = setup
    chaos = ChaosConfig(seed=0, logit_fault_rate=1.0, fault_lanes=(0,))
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4,
                     max_retries=1, chaos=chaos)
    h = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=8))
    loop.run()
    assert h.outcome == "failed"
    assert h.stats.retries == 2          # original + 1 retry, both poisoned
    assert loop.counters["failed_requests"] == 1
    assert loop.counters["quarantined_lanes"] == 2


def test_inert_chaos_is_bitwise_free(setup):
    """A zero-rate ChaosConfig (and no config at all) leaves the engine
    bitwise-identical: same greedy streams, ONE compiled block program,
    zero fault-path counters — the sentinel's all-clean `lax.cond` path
    is the same program the pre-chaos engine ran."""
    cfg, model, params = setup
    reqs = _mixed_reqs(cfg)
    base_loop, base = _serve(model, params, reqs, lanes=3)
    inert_loop, inert = _serve(model, params, reqs, lanes=3,
                               chaos=ChaosConfig())
    for a, b in zip(base, inert):
        assert a.tokens == b.tokens
    # the inert engine runs the EXACT programs the chaos-free one built
    # (the counter reads the shared jit cache: no new entries appeared)
    assert (inert_loop.counters["decode_block_programs"]
            == base_loop.counters["decode_block_programs"])
    for loop in (base_loop, inert_loop):
        for k in ("quarantined_lanes", "retried_requests", "failed_requests",
                  "deadline_expired", "cancelled_requests",
                  "rejected_requests", "degrade_down", "chaos_faults"):
            assert loop.counters[k] == 0, k


# -- deadlines + cancellation -------------------------------------------------


def test_deadline_frees_lane_within_one_block(setup):
    """A mid-decode deadline expiry terminates the lane at the next
    scheduler round — within ONE decode block — and the freed lane
    admits the waiting request, which completes normally."""
    cfg, model, params = setup
    # A dispatch stall burns the deadline while the lane is mid-stream.
    chaos = ChaosConfig(stall_blocks=(1,), stall_s=0.25)
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4, chaos=chaos)
    h_dead = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=64,
                                 deadline_s=0.2))
    h_next = loop.submit(Request(prompt=_prompt(cfg, 16, 2), max_new=4))
    loop.run()
    assert h_dead.outcome == "deadline"
    assert loop.counters["deadline_expired"] == 1
    # expired during the stall before block 1: block 1 still lands, the
    # round after it sweeps the lane — at most 2 blocks ever decoded
    assert 0 < len(h_dead.tokens) <= 2 * loop.block
    assert h_next.outcome == "done" and len(h_next.tokens) == 4


def test_cancel_active_and_queued(setup):
    """`RequestHandle.cancel()` resolves a QUEUED request without ever
    admitting it and terminates an ACTIVE lane with its partial tokens;
    the freed lane refills and the remaining request still completes."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    h_act = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=64))
    h_q = loop.submit(Request(prompt=_prompt(cfg, 16, 2), max_new=8))
    h_ok = loop.submit(Request(prompt=_prompt(cfg, 16, 3), max_new=4))
    loop.schedule()                        # admit h_act
    loop._step_block()                     # one block in flight
    assert h_act.cancel() and h_q.cancel()
    assert h_q.cancel()                    # idempotent while unresolved
    loop.run()
    assert not h_q.cancel()                # terminal → False
    assert h_act.outcome == "cancelled"
    assert len(h_act.tokens) == loop.block         # the one decoded block
    assert h_q.outcome == "cancelled" and h_q.tokens == []
    assert h_ok.outcome == "done" and len(h_ok.tokens) == 4
    assert loop.counters["cancelled_requests"] == 2


# -- degradation ladder -------------------------------------------------------


def test_degradation_ladder_steps_down_and_up(setup):
    """Sustained queue pressure steps the engine down the ladder (smaller
    decode block) and draining steps it back up — both transitions
    counted — while every request's token stream stays bitwise-identical
    to the undegraded engine (block size never enters the math)."""
    cfg, model, params = setup
    reqs = [dict(prompt=_prompt(cfg, 16, s), max_new=16) for s in range(6)]
    _, clean = _serve(model, params, reqs, lanes=2)

    loop, hs = _serve(model, params, reqs, lanes=2,
                      degrade=({"block": 2},), degrade_high=2)
    assert loop.counters["degrade_down"] >= 1
    assert loop.counters["degrade_up"] >= 1
    assert loop._degrade_level == 0        # recovered by drain time
    for h, ref in zip(hs, clean):
        assert h.outcome == "done"
        assert h.tokens == ref.tokens


def test_degradation_budget_cap_marks_degraded(setup):
    """A ladder level with `max_new_cap` trims NEW admissions' budgets;
    capped requests complete "done" with `stats.degraded=True` and
    exactly the cap's worth of tokens."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4,
                     degrade=({"block": 2, "max_new_cap": 4},),
                     degrade_high=1, degrade_low=0)
    hs = [loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=12))
          for s in range(4)]
    loop.run()
    assert loop.counters["degrade_down"] >= 1
    capped = [h for h in hs if h.stats.degraded]
    assert capped, "pressure never capped an admission"
    for h in capped:
        assert h.outcome == "done" and len(h.tokens) == 4
    # the first admission predates the pressure: full budget
    assert len(hs[0].tokens) == 12 and not hs[0].stats.degraded


# -- backpressure -------------------------------------------------------------


def test_backpressure_rejects_deterministically_by_priority(setup):
    """With `max_queue` full: an arriving HIGHER-priority request sheds
    the lowest-priority waiter (which resolves "rejected" with a
    `retry_after` hint); an equal-priority arrival is itself rejected.
    The outcome set is a pure function of the submission sequence —
    two identical runs resolve identically."""
    cfg, model, params = setup

    def run_once():
        loop = ServeLoop(model, params, lanes=1, eos=-1, block=4,
                         max_queue=2)
        hs = [loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=4,
                                  priority=0))
              for s in range(4)]           # queue bound 2 → last two reject
        hi = loop.submit(Request(prompt=_prompt(cfg, 16, 9), max_new=4,
                                 priority=1))
        loop.run()
        return loop, hs, hi

    loop, hs, hi = run_once()
    # hs[2]/hs[3] found the queue full of their own class → rejected
    # outright with a backpressure hint
    for h in (hs[2], hs[3]):
        assert h.outcome == "rejected"
        assert h.stats.retry_after >= 0.0
    # hi outranks the waiters: it sheds the LATEST prio-0 waiter (least
    # invested) and completes; the earliest waiter survives untouched
    assert hi.outcome == "done" and len(hi.tokens) == 4
    assert loop.counters["shed_requests"] == 1
    assert hs[1].outcome == "rejected"
    assert "shed" in hs[1].stats.detail
    assert hs[0].outcome == "done" and len(hs[0].tokens) == 4

    loop2, hs2, hi2 = run_once()
    assert [h.outcome for h in hs2] == [h.outcome for h in hs]
    assert hi2.outcome == hi.outcome
    assert loop2.counters["rejected_requests"] == \
        loop.counters["rejected_requests"]


def test_queue_flood_bounded_and_counted(setup):
    """A chaos queue flood against a bounded queue: the engine rejects
    the overflow deterministically, serves exactly what fits, and never
    wedges — every handle reaches a terminal outcome."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2, eos=-1, block=4, max_queue=3)
    hs = [loop.submit(Request(**kw))
          for kw in flood(cfg.vocab_size, 8, length=16, max_new=4, seed=5)]
    loop.run()
    outcomes = [h.outcome for h in hs]
    assert outcomes.count("done") == 3                # queue bound, pre-run
    assert outcomes.count("rejected") == 5
    assert loop.counters["rejected_requests"] == 5
    assert all(h.done for h in hs)


# -- structured rejection of unservable requests (silent-hang valve) ----------


def test_unservable_requests_reject_instead_of_hanging(setup):
    """max_new=0, an empty prompt, and a prompt longer than every pinned
    bucket each resolve to a structured rejection at submit time — the
    run loop never spins on work it cannot place — while a well-formed
    request on the same engine still completes."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4,
                     buckets=(16, 32))
    h_zero = loop.submit(Request(prompt=_prompt(cfg, 16, 1), max_new=0))
    h_empty = loop.submit(Request(prompt=np.zeros(0, np.int32), max_new=4))
    h_long = loop.submit(Request(prompt=_prompt(cfg, 64, 2), max_new=4))
    h_ok = loop.submit(Request(prompt=_prompt(cfg, 16, 3), max_new=4))
    for h in (h_zero, h_empty, h_long):
        assert h.done and h.outcome == "rejected"
        assert h.tokens == []
    assert "max_new" in h_zero.stats.detail
    assert "bucket" in h_long.stats.detail
    loop.run()
    assert h_ok.outcome == "done" and len(h_ok.tokens) == 4
    assert loop.counters["rejected_requests"] == 3


def test_legacy_prefill_only_still_done(setup):
    """The deprecated positional submit keeps its documented
    prefill-only contract: max_new=0 completes with outcome "done" and
    zero tokens (no rejection on the legacy surface)."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=1, eos=-1, block=4)
    with pytest.deprecated_call():
        rid = loop.submit(_prompt(cfg, 16, 1), max_new=0)
    loop.run()
    st = loop.stats[rid]
    assert st.outcome == "done"
    assert st.tokens == []
