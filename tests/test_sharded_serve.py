"""Data-sharded lane-parallel serving (the `ServeLoop(mesh=...)` path).

Run under forced host devices to exercise it on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharded_serve.py

Four coordinated guarantees:

  * token identity — the sharded engine replays the SAME arrival trace
    token-identically to the single-device engine (greedy bitwise,
    seeded-sampled identical per lane): lanes are independent, so layout
    must never change arithmetic;
  * shard-local admission — free lanes are tracked per shard, grouped
    prefill splices into ONE shard's lane rows at a time, and the
    per-shard token counters partition the emitted total;
  * preempt/resume composes with sharding — a preempted lane resumes
    token-identically wherever the scheduler re-splices it;
  * zero collectives — the compiled sharded decode block contains no
    all-gather / all-reduce / collective-permute on cache or knob
    operands (the shard_map body is a pure per-shard program).
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch import serve
from repro.launch.mesh import make_serve_mesh
from repro.launch.serve import Request, SamplingParams, ServeLoop
from repro.models.transformer import Model

jax.config.update("jax_platform_name", "cpu")

NDEV = len(jax.devices())
pytestmark = pytest.mark.skipif(
    NDEV < 2,
    reason="needs forced multi-device, e.g. "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

PRUNE = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                          sink_tokens=2, recent_window=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-3-2b"))
    model = Model(cfg, PRUNE)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, t, seed=0):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, t)


def _mixed_requests(cfg, n=10):
    """Staggered variable-length trace with a greedy/sampled knob mix."""
    reqs = []
    for i in range(n):
        kw = dict(prompt=_prompt(cfg, 5 + (7 * i) % 26, seed=i),
                  max_new=3 + i % 10)
        if i % 3 == 0:
            kw["sampling"] = SamplingParams(temperature=0.8, top_k=5)
            kw["sample_seed"] = 100 + i
        reqs.append(kw)
    return reqs


def _replay(model, params, reqs, lanes, mesh):
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=4, mesh=mesh)
    hs = [loop.submit(Request(**kw)) for kw in reqs]
    loop.run()
    return [h.tokens for h in hs], loop


# -- token identity ------------------------------------------------------------


def test_sharded_replay_token_identical(setup):
    """Same arrival trace, `data`-sharded lane batch vs single device:
    every request's stream is identical — greedy lanes bitwise, pinned-
    seed sampled lanes stream-identical (a lane's sampled stream is
    f(seed, tokens generated), independent of placement)."""
    cfg, model, params = setup
    reqs = _mixed_requests(cfg)
    toks_1, _ = _replay(model, params, reqs, lanes=NDEV, mesh=None)
    toks_n, loop = _replay(model, params, reqs, lanes=NDEV,
                           mesh=make_serve_mesh())
    assert toks_n == toks_1
    assert loop.shards == NDEV


def test_mesh_from_int_and_validation(setup):
    """`mesh=<int>` builds the serve mesh inline; lanes must divide the
    shard count."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2 * NDEV, mesh=NDEV)
    assert loop.shards == NDEV and loop.lanes_per_shard == 2
    with pytest.raises(AssertionError):
        ServeLoop(model, params, lanes=NDEV + 1, mesh=make_serve_mesh())


# -- shard-local admission -----------------------------------------------------


def test_shard_free_lane_accounting(setup):
    """`shard_free_lanes` partitions the free lanes by contiguous shard
    rows; grouped admission splices into ONE shard at a time; per-shard
    token counters partition the emitted total."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2 * NDEV, eos=-1, block=4,
                     mesh=make_serve_mesh())
    free = loop.shard_free_lanes()
    assert len(free) == NDEV
    assert sorted(l for fs in free for l in fs) == list(range(2 * NDEV))
    assert all(l // loop.lanes_per_shard == i
               for i, fs in enumerate(free) for l in fs)

    # a same-bucket pair admits as ONE group inside one shard's rows
    hs = [loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=8))
          for s in range(2)]
    loop.schedule()
    lanes = np.flatnonzero(loop.active)
    assert len(lanes) == 2
    assert len({int(l) // loop.lanes_per_shard for l in lanes}) == 1

    loop.run()
    agg = loop.aggregate()
    assert agg["shards"] == NDEV
    total = sum(agg[f"shard{i}_tokens"] for i in range(NDEV))
    assert total == sum(len(h.tokens) for h in hs)
    assert agg["tokens_per_dispatch"] == pytest.approx(
        total / loop.counters["decode_blocks"])


def test_admission_fills_least_loaded_shard(setup):
    """Each admission round targets the shard with the most free lanes,
    so load spreads across shards instead of packing shard 0."""
    cfg, model, params = setup
    loop = ServeLoop(model, params, lanes=2 * NDEV, eos=-1, block=4,
                     mesh=make_serve_mesh())
    for s in range(2 * NDEV):
        loop.submit(Request(prompt=_prompt(cfg, 16, s), max_new=8))
    loop.schedule()
    per_shard = np.asarray(loop.active).reshape(NDEV, -1).sum(axis=1)
    assert per_shard.sum() > 0
    # most-free targeting keeps the imbalance within one group's width
    assert per_shard.max() - per_shard.min() <= loop.lanes_per_shard
    loop.run()
    assert all(len(loop.stats[r].tokens) == 8 for r in loop.stats)


# -- preempt/resume across shards ----------------------------------------------


def test_preempt_resume_sharded_token_identical(setup):
    """Priority preemption under sharding: the victim requeues, resumes
    in whatever shard frees a lane, and still matches an uninterrupted
    single-device run token for token."""
    cfg, model, params = setup
    victim = dict(prompt=_prompt(cfg, 16, 1), max_new=12, priority=0,
                  sampling=SamplingParams(temperature=0.9, top_k=8),
                  sample_seed=13)

    solo = ServeLoop(model, params, lanes=1, block=4, eos=-1)
    h_ref = solo.submit(Request(**victim))
    solo.run()

    loop = ServeLoop(model, params, lanes=NDEV, eos=-1, block=4,
                     mesh=make_serve_mesh())
    h_v = loop.submit(Request(**victim))
    for s in range(NDEV - 1):
        loop.submit(Request(prompt=_prompt(cfg, 16, 90 + s), max_new=12,
                            priority=1))
    loop.schedule()                            # all lanes full
    loop._step_block()                         # one block into decode
    loop.submit(Request(prompt=_prompt(cfg, 16, 80), max_new=4, priority=5))
    loop.run()
    assert loop.counters["preemptions"] == 1
    assert h_v.stats.preemptions == 1
    assert h_v.tokens == h_ref.tokens
    assert len(h_v.tokens) == 12


# -- the no-collectives guard --------------------------------------------------


def test_sharded_block_compiles_collective_free(setup):
    """The lowered sharded decode block must contain ZERO cross-shard
    collectives: lanes are independent, so the shard_map body is a pure
    per-shard program (the all-greedy `jnp.any` fast path stays
    shard-local instead of lowering to an all-reduce)."""
    cfg, model, params = setup
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime.sharding import lane_shardings

    mesh = make_serve_mesh()
    lanes = NDEV
    state = model.init_decode_state(lanes)
    state = jax.device_put(state, lane_shardings(state, mesh))
    lane = NamedSharding(mesh, P("data"))
    keys = NamedSharding(mesh, P("data", None))
    args = (params, state,
            jax.device_put(jnp.zeros((lanes,), jnp.int32), lane),
            jax.device_put(jnp.ones((lanes,), bool), lane),
            jax.device_put(jnp.full((lanes,), 8, jnp.int32), lane),
            jax.device_put(jnp.full((lanes,), -1, jnp.int32), lane),
            jax.device_put(jnp.broadcast_to(jax.random.PRNGKey(0),
                                            (lanes, 2)), keys),
            jax.device_put(jnp.full((lanes,), 0.5, jnp.float32), lane),
            jax.device_put(jnp.full((lanes,), 4, jnp.int32), lane),
            jax.device_put(jnp.zeros((lanes,), jnp.float32), lane))
    fn = serve._lanes_block_fn(serve._model_key(model), 4, None, mesh)
    hlo = fn.lower(*args).compile().as_text()
    for op in ("all-gather", "all-reduce", "collective-permute",
               "all-to-all", "reduce-scatter"):
        assert len(re.findall(op, hlo)) == 0, (
            f"sharded decode block lowered a {op} — cross-shard traffic "
            f"on cache/knob operands")
