"""Behavioural tests for UniCAIM attention: selection fidelity, decode
equivalence with dense at full budget, prefill equals dense attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig
from repro.core import baselines
from repro.core.attention import chunked_causal_attention, decode_attention
from repro.core.cache import init_cache
from repro.core.pruning import prefill_and_prune
from repro.core.topk import exact_topk, gqa_group_scores, threshold_race

jax.config.update("jax_platform_name", "cpu")

B, Hq, Hk, d, N = 2, 4, 2, 32, 96


def _qkv(seed, t=N):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, t, d))
    k = jax.random.normal(ks[1], (B, Hk, t, d))
    v = jax.random.normal(ks[2], (B, Hk, t, d))
    return q, k, v


def _ref_causal(q, k, v, scale=None):
    t = q.shape[2]
    g = Hq // Hk
    scale = scale or 1.0 / np.sqrt(d)
    qg = q.reshape(B, Hk, g, t, d)
    logits = jnp.einsum("bhgtd,bhsd->bhgts", qg, k) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", p, v).reshape(B, Hq, t, d)
    return out, p.reshape(B, Hk, g, t, t).sum(axis=(2, 3))


@pytest.mark.parametrize("chunk", [16, 32, 96])
def test_chunked_attention_matches_dense(chunk):
    q, k, v = _qkv(0)
    out, acc = chunked_causal_attention(q, k, v, chunk=chunk)
    ref_out, ref_acc = _ref_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref_acc),
                               atol=1e-3)


def test_obs_window_accumulation():
    q, k, v = _qkv(1)
    _, acc_all = chunked_causal_attention(q, k, v, chunk=32)
    _, acc_win = chunked_causal_attention(q, k, v, chunk=32, obs_window=16)
    # window accumulation is strictly smaller and only counts last 16 rows
    assert (np.asarray(acc_win) <= np.asarray(acc_all) + 1e-6).all()
    g = Hq // Hk   # acc sums the whole GQA group: 16 rows × g heads
    assert np.asarray(acc_win).sum() == pytest.approx(16.0 * g * B * Hk,
                                                      rel=1e-3)


def test_decode_full_budget_topk_equals_dense():
    """With select_k == slots and no quant loss (8-bit), UniCAIM decode
    output must match dense attention over the same cache contents."""
    prune_u = PruneConfig(policy="unicaim", heavy_budget=24, reserve=8,
                          sink_tokens=2, recent_window=4, select_k=32,
                          score_bits=8, query_bits=8)
    prune_d = baselines.dense(32)
    cu = init_cache(B, Hk, d, 32, prune_u, jnp.float32)
    cd = init_cache(B, Hk, d, 32, prune_d, jnp.float32)
    outs = []
    for i in range(20):
        ks = jax.random.split(jax.random.PRNGKey(100 + i), 3)
        q1 = jax.random.normal(ks[0], (B, Hq, d))
        k1 = jax.random.normal(ks[1], (B, Hk, d))
        v1 = jax.random.normal(ks[2], (B, Hk, d))
        cu, ou = decode_attention(cu, q1, k1, v1, prune_u)
        cd, od = decode_attention(cd, q1, k1, v1, prune_d)
        outs.append((np.asarray(ou), np.asarray(od)))
    for ou, od in outs:
        np.testing.assert_allclose(ou, od, atol=1e-4)


def test_dynamic_selection_covers_true_topk():
    """3-bit approximate top-k must overlap heavily with exact top-k."""
    prune = PruneConfig(policy="unicaim", heavy_budget=56, reserve=8,
                        sink_tokens=0, recent_window=1, select_k=16,
                        score_bits=3, query_bits=4)
    cache = init_cache(B, Hk, d, 64, prune, jnp.float32)
    for i in range(64):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        cache, _ = decode_attention(
            cache, jax.random.normal(ks[0], (B, Hq, d)),
            jax.random.normal(ks[1], (B, Hk, d)),
            jax.random.normal(ks[2], (B, Hk, d)), prune)
    q = jax.random.normal(jax.random.PRNGKey(999), (B, Hq, d))
    exact = jnp.einsum("bhgd,bhsd->bhgs",
                       q.reshape(B, Hk, Hq // Hk, d),
                       cache.k).sum(axis=2)
    from repro.core import quant, scoring
    qq, qs = quant.quantize_query(q, 4)
    approx = gqa_group_scores(
        scoring.approx_scores(qq, qs, cache.kq, cache.kscale, cache.valid),
        Hk)
    _, ei = exact_topk(exact, 16)
    _, ai = exact_topk(approx, 16)
    overlaps = []
    for b in range(B):
        for h in range(Hk):
            overlaps.append(len(set(np.asarray(ei[b, h]).tolist())
                                & set(np.asarray(ai[b, h]).tolist())) / 16)
    assert np.mean(overlaps) > 0.75, overlaps


def test_threshold_race_selects_about_k():
    scores = jax.random.normal(jax.random.PRNGKey(3), (B, Hk, 128))
    for k in (8, 16, 32):
        mask = threshold_race(scores, k, iters=12)
        counts = np.asarray(mask.sum(-1))
        assert (counts >= 1).all()
        assert (np.abs(counts - k) <= max(3, k // 4)).all(), (k, counts)


def test_threshold_race_with_selection_bias_stays_near_k():
    """Regression: racing the ±1e30-biased scores directly degenerates —
    8 bisections over [-1e30, 1e30] leave ~1e27 resolution, so every
    finite score falls in one bucket and far more than k slots survive.
    Racing finite evictable scores only (protected unioned in afterwards)
    keeps the survivor count in [k, ~2k] with sinks/recents present."""
    from repro.core.topk import apply_selection_bias
    s = 128
    scores = jax.random.normal(jax.random.PRNGKey(5), (B, Hk, s))
    protected = jnp.zeros((B, Hk, s), bool).at[:, :, :6].set(True)
    invalid = jnp.zeros((B, Hk, s), bool).at[:, :, -16:].set(True)
    protected = protected & ~invalid
    for k in (16, 32):
        # the buggy formulation: race over the sentinel-biased scores —
        # the threshold can't resolve below ~1e27, so ~half of ALL finite
        # scores survive regardless of k
        biased = apply_selection_bias(scores, protected, invalid)
        degenerate = threshold_race(biased, k, iters=8)
        assert (np.asarray(degenerate.sum(-1)) > 1.5 * k).all()
        # the fixed formulation (what decode_attention now does)
        evictable = ~protected & ~invalid
        k_dyn = jnp.maximum(k - protected.sum(-1, keepdims=True), 1)
        mask = threshold_race(scores, k_dyn, iters=8,
                              eligible=evictable) | protected
        counts = np.asarray(mask.sum(-1))
        assert (counts >= k - 2).all(), (k, counts)
        assert (counts <= 2 * k).all(), (k, counts)
        # protected always survive, invalid never do
        assert np.asarray(mask & invalid).sum() == 0
        assert bool(np.asarray((mask & protected) == protected).all())


def test_threshold_mode_decode_survivor_count():
    """End-to-end: the threshold select_mode keeps ~select_k slots once
    the cache is full (it previously kept nearly everything)."""
    prune = PruneConfig(policy="unicaim", heavy_budget=56, reserve=8,
                        select_k=16, select_mode="threshold",
                        sink_tokens=2, recent_window=4)
    from repro.core import quant, scoring
    from repro.core.cache import protected_mask
    cache = init_cache(B, Hk, d, 64, prune, jnp.float32)
    from repro.core.attention import decode_attention
    for i in range(80):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        cache, _ = decode_attention(
            cache, jax.random.normal(ks[0], (B, Hq, d)),
            jax.random.normal(ks[1], (B, Hk, d)),
            jax.random.normal(ks[2], (B, Hk, d)), prune)
    q = jax.random.normal(jax.random.PRNGKey(123), (B, Hq, d))
    qq, qs = quant.quantize_query(q, prune.query_bits)
    grouped = gqa_group_scores(
        scoring.approx_scores(qq, qs, cache.kq, cache.kscale, cache.valid),
        Hk)
    prot = protected_mask(cache, prune)
    evictable = cache.valid & ~prot
    k_dyn = jnp.maximum(prune.select_k - prot.sum(-1, keepdims=True), 1)
    mask = threshold_race(grouped, k_dyn, prune.threshold_iters,
                          eligible=evictable) | prot
    counts = np.asarray(mask.sum(-1))
    assert (counts >= prune.select_k - 4).all(), counts
    assert (counts <= 2 * prune.select_k).all(), counts


def test_threshold_mode_decode_runs():
    prune = PruneConfig(policy="unicaim", heavy_budget=24, reserve=8,
                        select_k=8, select_mode="threshold",
                        sink_tokens=2, recent_window=4)
    cache = init_cache(B, Hk, d, 32, prune, jnp.float32)
    for i in range(10):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        cache, out = decode_attention(
            cache, jax.random.normal(ks[0], (B, Hq, d)),
            jax.random.normal(ks[1], (B, Hk, d)),
            jax.random.normal(ks[2], (B, Hk, d)), prune)
        assert not np.isnan(np.asarray(out)).any()


@pytest.mark.parametrize("chunk", [16, 96])
def test_chunked_attention_length_mask(chunk):
    """Right-padded inputs with a true-length mask reproduce exact-length
    attention: real-row outputs match, pad rows/cols add zero column mass,
    and the observation window anchors at the true length."""
    t, bucket = 40, 96
    q, k, v = _qkv(4, t=bucket)
    out_e, acc_e = chunked_causal_attention(q[:, :, :t], k[:, :, :t],
                                            v[:, :, :t], chunk=chunk)
    length = jnp.array([t, t])
    out_p, acc_p = chunked_causal_attention(q, k, v, chunk=chunk,
                                            length=length)
    np.testing.assert_allclose(np.asarray(out_p[:, :, :t]),
                               np.asarray(out_e), atol=1e-5)
    np.testing.assert_allclose(np.asarray(acc_p[:, :, :t]),
                               np.asarray(acc_e), atol=1e-5)
    assert np.abs(np.asarray(acc_p[:, :, t:])).max() == 0.0
    # per-lane lengths differ: each lane matches its own exact reference
    length2 = jnp.array([t, 24])
    _, acc_m = chunked_causal_attention(q, k, v, chunk=chunk,
                                        length=length2)
    _, acc_24 = chunked_causal_attention(q[1:, :, :24], k[1:, :, :24],
                                         v[1:, :, :24], chunk=chunk)
    np.testing.assert_allclose(np.asarray(acc_m[1, :, :24]),
                               np.asarray(acc_24[0]), atol=1e-5)
    assert np.abs(np.asarray(acc_m[1, :, 24:])).max() == 0.0
    # obs_window anchors at the true length, not the bucket
    _, acc_w = chunked_causal_attention(q, k, v, chunk=chunk, obs_window=8,
                                        length=length)
    _, acc_we = chunked_causal_attention(q[:, :, :t], k[:, :, :t],
                                         v[:, :, :t], chunk=chunk,
                                         obs_window=8)
    np.testing.assert_allclose(np.asarray(acc_w[:, :, :t]),
                               np.asarray(acc_we), atol=1e-5)


def test_prefill_fill_bucketed_matches_exact():
    """prefill_fill with a true-length mask: padded tokens never win the
    static top-k, inert pad slots are all-zero and invalid, and
    pos/fill/step reflect the real length, not the bucket."""
    import dataclasses as dc
    from repro.core.cache import prefill_fill
    prune = baselines.unicaim(heavy=24, reserve=8, select_k=8,
                              sink_tokens=2, recent_window=4)
    t, bucket = 20, 32                 # t < heavy_budget → inert slots
    _, k, v = _qkv(6, t=bucket)
    acc = jax.random.uniform(jax.random.PRNGKey(7), (B, Hk, bucket))
    acc = acc.at[:, :, t:].set(0.0)    # masked prefill guarantees this
    c_b = init_cache(B, Hk, d, prune.slots, prune, jnp.float32)
    filled_b = prefill_fill(c_b, k, v, acc, prune,
                            length=jnp.full((B,), t, jnp.int32))
    c_e = init_cache(B, Hk, d, prune.slots, prune, jnp.float32)
    filled_e = prefill_fill(c_e, k[:, :, :t], v[:, :, :t], acc[:, :, :t],
                            prune)
    for name, a, b in zip(filled_b._fields, filled_b, filled_e):
        if a is None:
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    assert (np.asarray(filled_b.fill) == t).all()
    assert (np.asarray(filled_b.step) == t).all()
    assert (np.asarray(filled_b.pos) < t).all()
    # int8 storage mirrors stay in lockstep too
    prune8 = dc.replace(prune, kv_dtype="int8")
    c8_b = init_cache(B, Hk, d, prune8.slots, prune8)
    f8_b = prefill_fill(c8_b, k, v, acc, prune8,
                        length=jnp.full((B,), t, jnp.int32))
    c8_e = init_cache(B, Hk, d, prune8.slots, prune8)
    f8_e = prefill_fill(c8_e, k[:, :, :t], v[:, :, :t], acc[:, :, :t],
                        prune8)
    for name, a, b in zip(f8_b._fields, f8_b, f8_e):
        if a is None:
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_prefill_and_prune_output_matches_dense():
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8)
    cache = init_cache(B, Hk, d, prune.slots, prune, jnp.float32)
    q, k, v = _qkv(5)
    cache, out = prefill_and_prune(cache, q, k, v, prune, chunk=32)
    ref_out, _ = _ref_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-4)


def test_blocked_selection_full_budget_exact():
    """select_blocks hierarchical selection is EXACT when k covers the
    whole cache (distributed CAM race, §Perf optimization)."""
    base = dict(policy="unicaim", heavy_budget=56, reserve=8,
                sink_tokens=2, recent_window=4, score_bits=8, query_bits=8)
    pr_blk = PruneConfig(select_k=64, select_blocks=4, **base)
    pr_dense = baselines.dense(64)
    cb = init_cache(B, Hk, d, 64, pr_blk, jnp.float32)
    cd = init_cache(B, Hk, d, 64, pr_dense, jnp.float32)
    for i in range(30):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        q1 = jax.random.normal(ks[0], (B, Hq, d))
        k1 = jax.random.normal(ks[1], (B, Hk, d))
        v1 = jax.random.normal(ks[2], (B, Hk, d))
        cb, ob = decode_attention(cb, q1, k1, v1, pr_blk)
        cd, od = decode_attention(cd, q1, k1, v1, pr_dense)
        np.testing.assert_allclose(np.asarray(ob), np.asarray(od),
                                   atol=1e-4)


def test_blocked_selection_tracks_global():
    """At half budget, block-local top-k stays close to global top-k."""
    base = dict(policy="unicaim", heavy_budget=56, reserve=8,
                sink_tokens=2, recent_window=4, score_bits=8, query_bits=8)
    pr_g = PruneConfig(select_k=32, select_blocks=1, **base)
    pr_b = PruneConfig(select_k=32, select_blocks=4, **base)
    cg = init_cache(B, Hk, d, 64, pr_g, jnp.float32)
    cb = init_cache(B, Hk, d, 64, pr_b, jnp.float32)
    errs = []
    for i in range(60):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        q1 = jax.random.normal(ks[0], (B, Hq, d))
        k1 = jax.random.normal(ks[1], (B, Hk, d))
        v1 = jax.random.normal(ks[2], (B, Hk, d))
        cg, og = decode_attention(cg, q1, k1, v1, pr_g)
        cb, ob = decode_attention(cb, q1, k1, v1, pr_b)
        errs.append(float(jnp.mean(jnp.abs(og - ob))))
    assert np.mean(errs) < 0.15, np.mean(errs)


def test_int8_kv_cache_drift_small():
    """int8 KV storage (§Perf memory knob; paper-faithful low-bit cells)
    changes decode outputs only marginally and removes the mirror copy."""
    base = dict(policy="unicaim", heavy_budget=56, reserve=8,
                sink_tokens=2, recent_window=4, select_k=32, query_bits=8)
    p_bf = PruneConfig(score_bits=8, **base)
    p_i8 = PruneConfig(score_bits=8, kv_dtype="int8", **base)
    c_bf = init_cache(B, Hk, d, 64, p_bf, jnp.float32)
    c_i8 = init_cache(B, Hk, d, 64, p_i8)
    assert c_i8.k.dtype == jnp.int8 and c_i8.kq is None
    errs = []
    for i in range(40):
        ks = jax.random.split(jax.random.PRNGKey(i), 3)
        q1 = jax.random.normal(ks[0], (B, Hq, d))
        k1 = jax.random.normal(ks[1], (B, Hk, d))
        v1 = jax.random.normal(ks[2], (B, Hk, d))
        c_bf, o1 = decode_attention(c_bf, q1, k1, v1, p_bf)
        c_i8, o2 = decode_attention(c_i8, q1, k1, v1, p_i8)
        errs.append(float(jnp.mean(jnp.abs(o1 - o2))))
    assert np.mean(errs) < 0.01, np.mean(errs)
    bytes_bf = sum(x.nbytes for x in jax.tree.leaves(c_bf))
    bytes_i8 = sum(x.nbytes for x in jax.tree.leaves(c_i8))
    assert bytes_i8 < bytes_bf / 2
