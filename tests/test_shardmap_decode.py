"""Runtime correctness of the shard_map blocked-decode path: on a real
(2 data × 4 model) mesh, the shard-local CAM race must produce the same
outputs as the single-device blocked reference."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import PruneConfig
    from repro.core.attention import decode_attention
    from repro.core.cache import init_cache
    from repro.runtime.sharding import use_mesh, decode_state_pspecs
    import jax.tree_util as jtu

    B, Hq, Hk, d, S = 4, 8, 4, 32, 64
    prune = PruneConfig(policy="unicaim", heavy_budget=56, reserve=8,
                        sink_tokens=2, recent_window=4, select_k=16,
                        select_blocks=4, score_bits=8, query_bits=8)

    def run(mesh):
        cache = init_cache(B, Hk, d, S, prune, jnp.float32)
        if mesh is not None:
            ctx = use_mesh(mesh)
            ctx.__enter__()
        outs = []
        step = jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v,
                                                           prune))
        for i in range(24):
            ks = jax.random.split(jax.random.PRNGKey(i), 3)
            q = jax.random.normal(ks[0], (B, Hq, d))
            kn = jax.random.normal(ks[1], (B, Hk, d))
            vn = jax.random.normal(ks[2], (B, Hk, d))
            cache, o = step(cache, q, kn, vn)
            outs.append(np.asarray(o))
        if mesh is not None:
            ctx.__exit__(None, None, None)
        return np.stack(outs)

    ref = run(None)                       # pure blocked path, 1 device
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    got = run(mesh)                       # shard_map path (blocks=model=4)
    np.testing.assert_allclose(got, ref, atol=2e-4)
    print("SHARDMAP_OK")
""")


def test_shardmap_decode_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "SHARDMAP_OK" in out.stdout, (out.stdout[-2000:],
                                         out.stderr[-3000:])


MLA_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import PruneConfig
    from repro.core import quant, scoring, topk
    from repro.core.cache import init_cache, protected_mask, write_token
    from repro.core.topk import NEG_INF
    from repro.models.mla import _mla_blocked_shardmap
    from repro.runtime.sharding import use_mesh

    # FULL budget (select_k == slots): every block keeps everything, so the
    # shard-local MLA race must equal dense latent attention exactly. The
    # comparison is at the latent-attention component level — full-model
    # logits go through MoE top-k routing, whose near-tie flips between two
    # differently-compiled (mesh vs no-mesh) programs are O(1) fp noise and
    # would mask a real shardmap bug.
    B, H, S, LAT, KVR = 4, 8, 64, 40, 32
    prune = PruneConfig(policy="unicaim", heavy_budget=S - 8, reserve=8,
                        sink_tokens=2, recent_window=4, select_k=S,
                        select_blocks=4, score_bits=8, query_bits=8)
    cache = init_cache(B, 1, LAT, S, prune, jnp.float32, latent=True)
    for i in range(50):
        u = jax.random.normal(jax.random.PRNGKey(i), (B, 1, LAT))
        cache = write_token(cache, u, None, prune)

    q_full = jax.random.normal(jax.random.PRNGKey(99), (B, H, LAT))
    qq, qs = quant.quantize_query(q_full, prune.query_bits)
    s_apx = scoring.approx_scores(qq, qs, cache.kq, cache.kscale,
                                  cache.valid)
    grouped = topk.gqa_group_scores(s_apx, 1)
    biased = topk.apply_selection_bias(
        grouped, protected_mask(cache, prune), ~cache.valid)
    scale_dim = 48

    u_all = cache.k[:, 0].astype(jnp.float32)
    logits = jnp.einsum("bhk,bsk->bhs", q_full, u_all) \\
        / jnp.sqrt(float(scale_dim))
    logits = jnp.where(cache.valid[:, 0][:, None, :], logits, NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)
    ref = jnp.einsum("bhs,bsk->bhk", pr, u_all[:, :, :KVR])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        got = _mla_blocked_shardmap(cache, q_full, biased, prune, mesh,
                                    KVR, scale_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)
    print("MLA_SHARDMAP_OK")
""")


def test_mla_shardmap_decode_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MLA_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MLA_SHARDMAP_OK" in out.stdout, (out.stdout[-2000:],
                                             out.stderr[-3000:])


MOE_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs.base import get_config, reduced
    from repro.models.moe import apply_moe, apply_moe_ep_shardmap, init_moe
    from repro.runtime.sharding import use_mesh

    cfg = reduced(get_config("grok-1-314b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_experts=8, top_k=2, capacity_factor=8.0))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_ref, _ = apply_moe(params, x, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        y_ep, _ = jax.jit(lambda p, x: apply_moe_ep_shardmap(
            p, x, cfg, mesh))(params, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=2e-4)
    print("MOE_EP_OK")
""")


def test_moe_ep_shardmap_matches_baseline_dispatch():
    """Expert-parallel all_to_all dispatch == sort-based dispatch when
    nothing drops (high capacity factor)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MOE_EP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MOE_EP_OK" in out.stdout, (out.stdout[-2000:],
                                       out.stderr[-3000:])
