"""Cache invariants: fixed-size, protection, in-place eviction (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property tests skip, the rest still run
    from conftest import given, settings, st  # noqa: F401

from repro.configs.base import PruneConfig
from repro.core.cache import (evictable_mask, init_cache, prefill_fill,
                              protected_mask, write_token)

jax.config.update("jax_platform_name", "cpu")


def _mk(policy="unicaim", slots=32, sink=2, recent=4, B=2, Hk=2, d=8):
    prune = PruneConfig(policy=policy, heavy_budget=slots - 8, reserve=8,
                        sink_tokens=sink, recent_window=recent,
                        select_k=8, score_bits=3)
    cache = init_cache(B, Hk, d, prune.slots, prune, dtype=jnp.float32)
    return prune, cache


def _write_n(cache, prune, n, seed=0):
    for i in range(n):
        k = jax.random.normal(jax.random.PRNGKey(seed * 997 + i),
                              (cache.k.shape[0], cache.k.shape[1],
                               cache.k.shape[3]))
        cache = write_token(cache, k, k + 1.0, prune)
    return cache


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 70), st.sampled_from(["unicaim", "h2o", "streaming"]))
def test_property_fixed_size_never_exceeded(n_tokens, policy):
    prune, cache = _mk(policy)
    cache = _write_n(cache, prune, n_tokens)
    valid_per_head = np.asarray(cache.valid.sum(axis=-1))
    assert (valid_per_head <= prune.slots).all()
    assert (np.asarray(cache.fill) == min(n_tokens, prune.slots)).all()
    assert (np.asarray(cache.step) == n_tokens).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(40, 80))
def test_property_sinks_never_evicted(n_tokens):
    prune, cache = _mk("unicaim", sink=3)
    cache = _write_n(cache, prune, n_tokens)
    pos = np.asarray(cache.pos)
    for b in range(pos.shape[0]):
        for h in range(pos.shape[1]):
            kept = set(pos[b, h][pos[b, h] >= 0].tolist())
            assert {0, 1, 2} <= kept, f"sinks evicted: {sorted(kept)[:6]}"


@settings(max_examples=10, deadline=None)
@given(st.integers(40, 80), st.integers(2, 8))
def test_property_recent_window_kept(n_tokens, recent):
    prune, cache = _mk("unicaim", recent=recent)
    cache = _write_n(cache, prune, n_tokens)
    pos = np.asarray(cache.pos)
    for b in range(pos.shape[0]):
        for h in range(pos.shape[1]):
            kept = set(pos[b, h][pos[b, h] >= 0].tolist())
            want = set(range(max(0, n_tokens - recent), n_tokens))
            assert want <= kept


def test_eviction_targets_lowest_accumulated_score():
    prune, cache = _mk("h2o", slots=16, sink=0, recent=1)
    cache = _write_n(cache, prune, 16)             # full
    # plant known accumulated scores: slot 5 lowest
    acc = np.arange(16, dtype=np.float32)[None, None, :] + 10.0
    acc[:, :, 5] = 0.1
    cache = cache._replace(acc=jnp.asarray(np.broadcast_to(acc, cache.acc.shape)))
    evicted_pos = int(cache.pos[0, 0, 5])
    k = jnp.ones((2, 2, 8))
    cache2 = write_token(cache, k, k, prune)
    assert int(cache2.pos[0, 0, 5]) == 16           # new token in slot 5
    pos_now = set(np.asarray(cache2.pos[0, 0]).tolist())
    assert evicted_pos not in pos_now


def test_streaming_ring_eviction_is_positional():
    prune, cache = _mk("streaming", slots=16, sink=2)
    cache = _write_n(cache, prune, 30)
    pos = np.asarray(cache.pos[0, 0])
    kept = set(pos[pos >= 0].tolist())
    assert {0, 1} <= kept                           # sinks
    # the most recent window tokens are all present
    assert set(range(30 - 14, 30)) <= kept


def test_prefill_fill_selects_heavy_tokens():
    prune, cache = _mk("unicaim", slots=32, sink=2, recent=4)
    B, Hk, N, d = 2, 2, 64, 8
    k = jax.random.normal(jax.random.PRNGKey(0), (B, Hk, N, d))
    v = k * 2
    acc = jax.random.uniform(jax.random.PRNGKey(1), (B, Hk, N))
    cache = prefill_fill(cache, k, v, acc, prune)
    keep = prune.heavy_budget
    assert (np.asarray(cache.fill) == keep).all()
    accn = np.asarray(acc)
    pos = np.asarray(cache.pos)
    for b in range(B):
        for h in range(Hk):
            chosen = pos[b, h][pos[b, h] >= 0]
            # forced: sinks + recent
            assert {0, 1} <= set(chosen.tolist())
            assert set(range(N - 4, N)) <= set(chosen.tolist())
            # the rest are the top scorers among free positions
            free = [i for i in range(N)
                    if i >= 2 and i < N - 4]
            free_sorted = sorted(free, key=lambda i: -accn[b, h, i])
            n_free = keep - 2 - 4
            expect = set(free_sorted[:n_free]) | {0, 1} | set(range(N - 4, N))
            assert set(chosen.tolist()) == expect


def test_protected_evictable_partition():
    prune, cache = _mk("unicaim")
    cache = _write_n(cache, prune, 40)
    prot = np.asarray(protected_mask(cache, prune))
    evict = np.asarray(evictable_mask(cache, prune))
    valid = np.asarray(cache.valid)
    assert not (prot & evict).any()
    assert ((prot | evict) == valid).all()
