"""Substrate tests: optimizer (incl. int8 states), schedules, gradient
compression, data pipeline, checkpoint manager."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline, MemmapSource, SyntheticSource
from repro.optim import adamw, compression, schedule

jax.config.update("jax_platform_name", "cpu")


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,)), "b": jnp.zeros((4, 8))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss, target


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_converges(quantized):
    params, loss, target = _quad_problem()
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                            quantized_state=quantized)
    state = adamw.init(params, cfg)
    step = jax.jit(lambda p, s: adamw.update(jax.grad(loss)(p), s, p, cfg))
    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)
    assert float(jnp.abs(params["b"]).max()) < 0.05


def test_adamw_quantized_state_bytes():
    params = {"w": jnp.zeros((256, 256))}
    st_q = adamw.init(params, adamw.AdamWConfig(quantized_state=True))
    st_f = adamw.init(params, adamw.AdamWConfig(quantized_state=False))
    q_bytes = sum(x.nbytes for x in jax.tree.leaves(st_q.m))
    f_bytes = sum(x.nbytes for x in jax.tree.leaves(st_f.m))
    assert q_bytes < f_bytes / 3.5          # ~int8 + per-row scale


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros((4,))}
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    state = adamw.init(params, cfg)
    huge = {"w": jnp.full((4,), 1e9)}
    new, _ = adamw.update(huge, state, params, cfg)
    assert float(jnp.abs(new["w"]).max()) < 10.0


def test_warmup_cosine_shape():
    s = jnp.arange(0, 1000)
    lr = schedule.warmup_cosine(s, 1e-3, warmup=100, total=1000)
    assert 0.0 < float(lr[0]) <= 1.01e-5    # warm but never a zero step
    assert float(lr[99]) <= 1e-3 * 1.0001
    assert float(lr[100]) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr[-1]) < 3e-4             # decayed toward the floor


def test_gradient_compression_error_feedback():
    grads = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (128,))}
    ef = compression.init_error_feedback(grads)
    (q, s), ef2 = compression.compress_with_feedback(grads, ef)
    deq = jax.tree.map(compression.dequantize_grad, q, s)
    # feedback holds exactly the quantization residual
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(grads[k] - deq[k]), np.asarray(ef2.residual[k]),
            atol=1e-6)
    # next-step compression re-injects the residual → bias-free on average
    (q2, s2), ef3 = compression.compress_with_feedback(grads, ef2)
    deq2 = jax.tree.map(compression.dequantize_grad, q2, s2)
    two_step = jax.tree.map(lambda a, b: a + b, deq, deq2)
    for k in grads:
        np.testing.assert_allclose(np.asarray(two_step[k]) / 2.0,
                                   np.asarray(grads[k]),
                                   atol=float(jnp.abs(grads[k]).max()) / 100)


def test_synthetic_source_deterministic():
    src = SyntheticSource(vocab_size=100, seq_len=32, seed=3)
    a = src.batch(7, 4)
    b = src.batch(7, 4)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 32)
    assert a.min() >= 0 and a.max() < 100
    assert not np.array_equal(a, src.batch(8, 4))


def test_memmap_source(tmp_path):
    path = os.path.join(tmp_path, "toks.bin")
    np.arange(10000, dtype=np.uint16).tofile(path)
    src = MemmapSource(path, seq_len=64)
    b = src.batch(0, 3)
    assert b.shape == (3, 64)
    # windows are contiguous slices of the file
    assert (np.diff(b, axis=1) == 1).all()


def test_pipeline_prefetch_and_shard_slice(tmp_path):
    src = SyntheticSource(50, 16, seed=0)
    pipe = DataPipeline(src, global_batch=8, process_index=1,
                        process_count=2)
    batch = next(pipe)
    assert batch["tokens"].shape == (4, 16)
    full = src.batch(0, 8)
    np.testing.assert_array_equal(np.asarray(batch["tokens"]), full[4:])
    pipe.close()


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"w": jnp.arange(6.0).reshape(2, 3),
             "opt": {"m": jnp.ones((4,)), "step": jnp.asarray(3)}}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, state), block=True)
    assert mgr.all_steps() == [20, 30]       # retention pruned step 10
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    out = mgr.restore(30, like)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(state["w"]) + 30)


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    state = {"w": jnp.ones((8,))}
    mgr.save(5, state, block=True)
    # a .tmp dir must never be visible as a restorable step
    assert mgr.all_steps() == [5]
    for name in os.listdir(tmp_path):
        assert not name.endswith(".tmp")
