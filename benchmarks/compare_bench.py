"""Warn-only regression check: fresh smoke-bench JSON vs committed baseline.

Committed baselines live in ``benchmarks/baselines/`` (the smoke sweep's
outputs in the repo root are gitignored); refresh them by copying a fresh
smoke run's ``BENCH_*.json`` over them in the same PR that changes the
performance. CI runs::

    python benchmarks/compare_bench.py \
        benchmarks/baselines/BENCH_serve.json BENCH_serve.json

Throughput-style keys (``*tok_s*``) warn when the fresh value drops below
``TOL`` of the baseline; count-style keys (``*compile*`` / ``*dispatch*``
/ ``*windows*``) warn when the fresh value EXCEEDS the baseline
(dispatch/compile counts are deterministic — more of them means an
admission/bucketing/windowing regression, not noise); latency-style keys
(``*_us*``, lower is better) warn when the fresh value exceeds
``1/TOL`` of the baseline; ratio-style keys (``*speedup*`` /
``*reduction*``, higher is better) warn like throughput. Prefix-cache
keys are higher-better and matched BEFORE the generic count rule:
share-style keys (``*hit_rate*`` / ``*dedup*``, deterministic fractions
of admissions served from cache) and reuse-count keys
(``*copies*`` / ``*tokens_reused*`` / ``*_hits*``) warn when the fresh
value drops below the baseline — fewer cache hits on identical traffic
means the admission path stopped consulting or populating the trie. Everything else
— including the string-valued decision records (``fused_auto_*``) — is
informational. The exit code is always 0: shared CI runners are far too
noisy for a hard wall-clock gate, so this is a trajectory tripwire, not
a merge blocker. Warnings use GitHub ``::warning::`` annotations so they
surface on the PR checks page.
"""
from __future__ import annotations

import json
import sys

TOL = 0.7        # throughput may dip to 70% of baseline before warning


def classify(key: str) -> str:
    if "tok_s" in key:
        return "throughput"
    # prefix-cache reuse keys are HIGHER-better; they must outrank the
    # generic lower-better count rule (e.g. "copies" are not dispatches)
    if "hit_rate" in key or "dedup" in key:
        return "share"
    if "copies" in key or "tokens_reused" in key or key.endswith("_hits"):
        return "reuse"
    if "compile" in key or "dispatch" in key or "windows" in key:
        return "count"
    if "speedup" in key or "reduction" in key:
        return "ratio"
    if "_us" in key:
        return "latency"
    return "info"


def compare(baseline: dict, fresh: dict) -> list:
    """[(level, message)] — level 'warning' or 'notice'."""
    out = []
    for key in sorted(set(baseline) & set(fresh)):
        base, cur = baseline[key], fresh[key]
        if not isinstance(base, (int, float)) \
                or not isinstance(cur, (int, float)):
            continue
        kind = classify(key)
        if kind == "throughput" and cur < TOL * base:
            out.append(("warning",
                        f"{key}: {cur:.1f} tok/s < {TOL:.0%} of committed "
                        f"baseline {base:.1f}"))
        elif kind == "count" and cur > base:
            out.append(("warning",
                        f"{key}: {cur:.0f} exceeds committed baseline "
                        f"{base:.0f} (dispatch/compile regression)"))
        elif kind == "latency" and cur * TOL > base:
            out.append(("warning",
                        f"{key}: {cur:.1f}us > {1 / TOL:.2f}x committed "
                        f"baseline {base:.1f}us (latency regression)"))
        elif kind == "ratio" and cur < TOL * base:
            out.append(("warning",
                        f"{key}: {cur:.2f} < {TOL:.0%} of committed "
                        f"baseline ratio {base:.2f}"))
        elif kind in ("share", "reuse") and cur < base:
            out.append(("warning",
                        f"{key}: {cur:g} below committed baseline {base:g} "
                        f"(prefix-cache reuse regression — identical "
                        f"traffic should hit at least as often)"))
        else:
            out.append(("notice", f"{key}: {base:g} -> {cur:g}"))
    for key in sorted(set(baseline) - set(fresh)):
        out.append(("warning", f"{key}: present in baseline, missing from "
                               "fresh run"))
    return out


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print("usage: compare_bench.py <baseline.json> <fresh.json>")
        return 0
    try:
        with open(argv[0]) as f:
            baseline = json.load(f)
        with open(argv[1]) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:    # warn-only by design
        print(f"::warning::bench compare skipped: {e}")
        return 0
    warned = 0
    for level, msg in compare(baseline, fresh):
        if level == "warning":
            warned += 1
            print(f"::warning::{msg}")
        else:
            print(msg)
    print(f"{warned} warning(s) vs committed baseline (warn-only, "
          "never fails the build)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
