"""Regression check: fresh smoke-bench JSON vs committed baseline.

Committed baselines live in ``benchmarks/baselines/`` (the smoke sweep's
outputs in the repo root are gitignored); refresh them by copying a fresh
smoke run's ``BENCH_*.json`` over them in the same PR that changes the
performance. CI runs::

    python benchmarks/compare_bench.py --fail-on-counts \
        benchmarks/baselines/BENCH_serve.json BENCH_serve.json

Throughput-style keys (``*tok_s*``) warn when the fresh value drops below
``TOL`` of the baseline; count-style keys (``*compile*`` / ``*dispatch*``
/ ``*windows*``) warn when the fresh value EXCEEDS the baseline
(dispatch/compile counts are deterministic — more of them means an
admission/bucketing/windowing regression, not noise); latency-style keys
(``*_us*``, lower is better) warn when the fresh value exceeds
``1/TOL`` of the baseline; ratio-style keys (``*speedup*`` /
``*reduction*``, higher is better) warn like throughput. Prefix-cache
keys are higher-better and matched BEFORE the generic count rule:
share-style keys (``*hit_rate*`` / ``*dedup*``, deterministic fractions
of admissions served from cache, plus ``*attain*`` SLO-attainment
fractions) and reuse-count keys (``*copies*`` / ``*tokens_reused*`` /
``*_hits*`` / ``*reserv*``) warn when the fresh value drops below the
baseline — fewer cache hits or reservations on identical traffic means
the admission path stopped consulting the trie / predicting drains.
Scheduler counters are count-class: ``*preempt*`` (more evictions on
the same priority traffic means the scheduler evicts lanes it should
not) and ``*block_programs*`` (more than one compiled decode block per
(steps, window) means the per-lane knob arrays started recompiling)
fail under ``--fail-on-counts`` exactly like dispatch/compile counts.
Sharded-serving rows are count-class too (``*shard*`` lane/shard/request
counts and tokens-per-dispatch are deterministic on the fixed saturation
trace) except the wall-clock TTFT rows, which stay informational, and
the ``*identical*`` replay flag, which is share-class so a drop below
the committed 1.0 warns (the tier-1 sharded suite hard-fails it).
Fault-tolerance counters (``*retr*`` / ``*reject*`` / ``*degrad*`` /
``*quarantin*`` / ``*cancel*`` / ``*deadline*`` / ``*shed*``) are
count-class: the chaos harness injects by (seed, block index), so the
recovery counts on the committed fault sweep are exactly reproducible —
growth means a recovery-path regression, not noise.
``*_p50`` keys are sibling medians of the min-based ``*_us`` rows
(see ``common.Timing``): they are never compared against the baseline,
but when a fresh run's p50/min ratio exceeds ``NOISE_RATIO`` the run is
flagged as noisy — its wall-clock ratios should not be trusted.
Everything else — including the string-valued decision records
(``fused_auto_*``, ``donation``) — is informational.

Exit code: 0 by default — shared CI runners are far too noisy for a hard
wall-clock gate, so timing rows are a trajectory tripwire, not a merge
blocker. ``--fail-on-counts`` makes DETERMINISTIC count-class
regressions (more compiles/dispatches/windows than the committed
baseline) exit 1; those do not depend on the wall clock, so there is no
noise excuse. Keys new in the fresh run or missing from it never fail.
Warnings use GitHub ``::warning::`` annotations so they surface on the
PR checks page.
"""
from __future__ import annotations

import json
import sys

TOL = 0.7          # throughput may dip to 70% of baseline before warning
NOISE_RATIO = 2.0  # p50/min above this flags the run as noisy


def classify(key: str) -> str:
    if key.endswith("_p50"):
        return "p50"
    if "tok_s" in key:
        return "throughput"
    # prefix-cache reuse keys are HIGHER-better; they must outrank the
    # generic lower-better count rule (e.g. "copies" are not dispatches)
    if "hit_rate" in key or "dedup" in key or "attain" in key \
            or "identical" in key:
        return "share"
    if "copies" in key or "tokens_reused" in key or key.endswith("_hits") \
            or "reserv" in key:
        return "reuse"
    # fault-tolerance counters are deterministic under the seeded chaos
    # harness: more retries/quarantines/rejections/degradations on the
    # identical injected-fault trace means a recovery-path regression
    if "retr" in key or "reject" in key or "degrad" in key \
            or "quarantin" in key or "cancel" in key or "deadline" in key \
            or "shed" in key:
        return "count"
    if "compile" in key or "dispatch" in key or "windows" in key \
            or "preempt" in key or "block_programs" in key:
        return "count"
    if "speedup" in key or "reduction" in key:
        return "ratio"
    # sharded-serving rows: lane/shard/request counts, replay-identity
    # flags, and tokens-per-dispatch are deterministic on the fixed
    # saturation trace, so they count-gate like dispatch counters.
    # TTFT rows are wall-clock seconds and stay informational.
    if "shard" in key and "ttft" not in key:
        return "count"
    if "_us" in key:
        return "latency"
    return "info"


def noise_checks(fresh: dict) -> list:
    """[(level, kind, message)] — flag rows whose p50/min ratio says the
    run was too noisy for its min-based ratios to mean much."""
    out = []
    for key, p50 in sorted(fresh.items()):
        if not key.endswith("_p50") or not isinstance(p50, (int, float)):
            continue
        lo = fresh.get(key[:-len("_p50")])
        if not isinstance(lo, (int, float)) or lo <= 0:
            continue
        if p50 / lo > NOISE_RATIO:
            out.append(("warning", "noise",
                        f"{key[:-len('_p50')]}: noisy run — p50 "
                        f"{p50:.1f}us is {p50 / lo:.1f}x the min "
                        f"{lo:.1f}us (> {NOISE_RATIO:g}x); treat this "
                        f"run's latency ratios as unreliable"))
    return out


def compare(baseline: dict, fresh: dict) -> list:
    """[(level, kind, message)] — level 'warning' or 'notice'."""
    out = []
    for key in sorted(set(baseline) & set(fresh)):
        base, cur = baseline[key], fresh[key]
        if not isinstance(base, (int, float)) \
                or not isinstance(cur, (int, float)):
            continue
        kind = classify(key)
        if kind == "throughput" and cur < TOL * base:
            out.append(("warning", kind,
                        f"{key}: {cur:.1f} tok/s < {TOL:.0%} of committed "
                        f"baseline {base:.1f}"))
        elif kind == "count" and cur > base:
            out.append(("warning", kind,
                        f"{key}: {cur:.0f} exceeds committed baseline "
                        f"{base:.0f} (dispatch/compile regression)"))
        elif kind == "latency" and cur * TOL > base:
            out.append(("warning", kind,
                        f"{key}: {cur:.1f}us > {1 / TOL:.2f}x committed "
                        f"baseline {base:.1f}us (latency regression)"))
        elif kind == "ratio" and cur < TOL * base:
            out.append(("warning", kind,
                        f"{key}: {cur:.2f} < {TOL:.0%} of committed "
                        f"baseline ratio {base:.2f}"))
        elif kind in ("share", "reuse") and cur < base:
            out.append(("warning", kind,
                        f"{key}: {cur:g} below committed baseline {base:g} "
                        f"(prefix-cache reuse regression — identical "
                        f"traffic should hit at least as often)"))
        elif kind != "p50":
            out.append(("notice", kind, f"{key}: {base:g} -> {cur:g}"))
    for key in sorted(set(baseline) - set(fresh)):
        out.append(("warning", "missing",
                    f"{key}: present in baseline, missing from fresh run"))
    out.extend(noise_checks(fresh))
    return out


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    fail_on_counts = "--fail-on-counts" in argv
    argv = [a for a in argv if a != "--fail-on-counts"]
    if len(argv) != 2:
        print("usage: compare_bench.py [--fail-on-counts] "
              "<baseline.json> <fresh.json>")
        return 0
    try:
        with open(argv[0]) as f:
            baseline = json.load(f)
        with open(argv[1]) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:    # warn-only by design
        print(f"::warning::bench compare skipped: {e}")
        return 0
    warned = failed = 0
    for level, kind, msg in compare(baseline, fresh):
        if level == "warning":
            warned += 1
            if fail_on_counts and kind == "count":
                failed += 1
                print(f"::error::{msg}")
            else:
                print(f"::warning::{msg}")
        else:
            print(msg)
    if failed:
        print(f"{failed} count regression(s) vs committed baseline "
              "(--fail-on-counts: deterministic counters must not grow)")
        return 1
    print(f"{warned} warning(s) vs committed baseline (timing rows are "
          "warn-only; counts fail only under --fail-on-counts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
