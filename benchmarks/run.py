"""Benchmark harness — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (EXPERIMENTS.md indexes them).
  Table II → bench_aedp        Fig 10 → bench_footprint
  Fig 11  → bench_energy       Fig 12 → bench_latency
  Fig 13  → bench_accuracy     Fig 9  → bench_fidelity

A bench whose ``run()`` returns a dict additionally gets a
machine-readable ``BENCH_<name>.json`` written next to the cwd under
``--smoke`` (CI uploads these — the serving trajectory lives in
``BENCH_serve.json``: tok/s, p50/p99 ttft, prefill compile counts).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ("aedp", "footprint", "energy", "latency", "fidelity",
           "accuracy", "needle", "serve")


SMOKE_BENCHES = ("aedp", "latency", "serve")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI sweep: "
                         f"{SMOKE_BENCHES} with shrunk configs")
    args = ap.parse_args(argv)
    if args.smoke:
        from benchmarks import common
        common.set_smoke(True)
    wanted = (args.only.split(",") if args.only
              else list(SMOKE_BENCHES) if args.smoke else list(BENCHES))
    print("name,us_per_call,derived")
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        summary = mod.run()
        if args.smoke and isinstance(summary, dict) and summary:
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump(summary, f, indent=2, sort_keys=True)
            print(f"wrote {path}", file=sys.stderr)
        print(f"bench_{name}_total,{(time.time() - t0) * 1e6:.0f},done",
              file=sys.stderr)
    if args.smoke:
        # cross-PR trajectory: committed baseline history + this run,
        # tabulated to stdout and plotted to BENCH_trajectory.{svg,png}
        # (CI uploads the pair with the BENCH_*.json artifacts)
        from benchmarks import trajectory
        print()
        trajectory.main(["--plot"])


if __name__ == "__main__":
    main()
