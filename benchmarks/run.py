"""Benchmark harness — one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (EXPERIMENTS.md indexes them).
  Table II → bench_aedp        Fig 10 → bench_footprint
  Fig 11  → bench_energy       Fig 12 → bench_latency
  Fig 13  → bench_accuracy     Fig 9  → bench_fidelity
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ("aedp", "footprint", "energy", "latency", "fidelity",
           "accuracy", "needle", "serve")


SMOKE_BENCHES = ("aedp", "latency", "serve")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI sweep: "
                         f"{SMOKE_BENCHES} with shrunk configs")
    args = ap.parse_args(argv)
    if args.smoke:
        from benchmarks import common
        common.set_smoke(True)
    wanted = (args.only.split(",") if args.only
              else list(SMOKE_BENCHES) if args.smoke else list(BENCHES))
    print("name,us_per_call,derived")
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        mod.run()
        print(f"bench_{name}_total,{(time.time() - t0) * 1e6:.0f},done",
              file=sys.stderr)


if __name__ == "__main__":
    main()
