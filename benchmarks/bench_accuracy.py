"""Paper Fig. 13 — application-level accuracy at matched cache ratios.

A small LM is trained on structured synthetic data, then decoded under each
policy at cache ratios {100%, 50%, 20%}. Fidelity vs the dense-cache
reference is measured as next-token top-1 agreement and softmax L1 drift
over a generation rollout. The paper's claim to reproduce: UniCAIM ≈ dense,
and UniCAIM > SnapKV/StreamingLLM at the same ratio."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_trained_model
from repro.core import baselines
from repro.models.transformer import Model

PROMPT = 96
STEPS = 24


def _policy(name: str, budget: int):
    reserve = max(8, budget // 8)
    heavy = budget - reserve
    # select_k at half the budget: the comparison probes the RETENTION
    # policy at matched cache ratios; a tiny top-k on a tiny cache would
    # double-prune unicaim relative to the attend-everything baselines
    k = max(16, budget // 2)
    if name == "unicaim":
        return baselines.unicaim(heavy=heavy, reserve=reserve, select_k=k,
                                 score_bits=3, sink_tokens=2,
                                 recent_window=8)
    if name == "h2o":
        return baselines.h2o(heavy=heavy, reserve=reserve, recent=8)
    if name == "snapkv":
        return baselines.snapkv(heavy=heavy, reserve=reserve,
                                obs_window=16, recent=8)
    if name == "streaming":
        return baselines.streaming(budget, sinks=2)
    raise ValueError(name)


def rollout(cfg, params, prune, toks, steps=STEPS):
    model = Model(cfg, prune)
    lg, state = jax.jit(model.prefill)(params, {"tokens": toks})
    decode = jax.jit(model.decode_step)
    probs, ids = [], []
    tok = jnp.argmax(lg, -1)
    for _ in range(steps):
        ids.append(np.asarray(tok))
        lg, state = decode(params, state, tok)
        probs.append(np.asarray(jax.nn.softmax(lg, -1)))
        tok = jnp.argmax(lg, -1)
    return np.stack(ids, 1), np.stack(probs, 1)


def run():
    cfg, params, src = tiny_trained_model()
    toks = jnp.asarray(src.batch(9999, 4)[:, :PROMPT])
    ref_ids, ref_probs = rollout(cfg, params, baselines.dense(PROMPT + STEPS + 8),
                                 toks)
    for ratio in (1.0, 0.5, 0.2):
        budget = max(24, int(PROMPT * ratio))
        for name in ("unicaim", "h2o", "snapkv", "streaming"):
            ids, probs = rollout(cfg, params, _policy(name, budget), toks)
            agree = float((ids == ref_ids).mean())
            drift = float(np.abs(probs - ref_probs).sum(-1).mean())
            emit(f"accuracy_{name}_r{int(ratio * 100)}", 0.0,
                 f"top1_agreement={agree:.3f};prob_l1_drift={drift:.3f}")


if __name__ == "__main__":
    run()
