"""Paper Fig. 9 — approximate-score robustness. The FeFET linearity /
device-variation sweep maps to: top-k selection overlap between the
quantized CAM scores and exact scores, as a function of score_bits, with
multiplicative scale noise emulating device-to-device variation (σ=54mV
→ relative conductance noise)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import quant, scoring
from repro.core.topk import exact_topk

B, HK, S, D, K = 4, 4, 512, 128, 64


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, HK, D))
    kcache = jax.random.normal(ks[1], (B, HK, S, D))
    valid = jnp.ones((B, HK, S), bool)
    exact = jnp.einsum("bhd,bhsd->bhs", q, kcache)
    _, ref_idx = exact_topk(exact, K)
    ref_sets = [set(np.asarray(ref_idx[b, h]).tolist())
                for b in range(B) for h in range(HK)]
    for bits in (1, 2, 3, 4, 8):
        for noise in (0.0, 0.05):
            kq, kscale = quant.quantize(kcache, bits)
            if noise:
                nz = 1.0 + noise * jax.random.normal(ks[2], kscale.shape)
                kscale = kscale * nz
            qq, qs = quant.quantize_query(q, max(bits, 4))
            approx = scoring.approx_scores(qq, qs, kq, kscale, valid)
            _, idx = exact_topk(approx, K)
            sets = [set(np.asarray(idx[b, h]).tolist())
                    for b in range(B) for h in range(HK)]
            overlap = np.mean([len(a & r) / K
                               for a, r in zip(sets, ref_sets)])
            emit(f"fidelity_bits{bits}_noise{int(noise * 100)}", 0.0,
                 f"topk_overlap={overlap:.3f}")


if __name__ == "__main__":
    run()
