"""Cross-PR performance trajectory from committed bench baselines.

Every PR that moves performance refreshes ``benchmarks/baselines/
BENCH_*.json``, so the git history of those files IS the repo's
performance trajectory — one column per committing PR. This module
renders it as a table (plus a ``fresh`` column from the current run's
``./BENCH_*.json`` when present, with a delta against the newest
committed column), and is appended to the ``benchmarks/run.py --smoke``
output so every CI bench run shows where the numbers came from, not
just where they are.

Standalone::

    python benchmarks/trajectory.py [--revs 6] [names...]

Wall-clock caveat: columns come from different machines/runs — the
trajectory shows direction and order of magnitude, not tight ratios
(deterministic counters like dispatch counts ARE exact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASE_DIR = os.path.join(ROOT, "benchmarks", "baselines")

# the headline metrics worth a trajectory row, per bench file (matched
# as prefixes so sweep families stay together without enumerating fills)
KEY_PREFIXES = {
    "latency": ("unicaim_us_ctx", "dense_us_ctx", "unicaim_scan_us_ctx",
                "unicaim_win_us_fill", "unicaim_inplace_us_fill",
                "win_speedup", "inplace_speedup", "speedup_vs_dense",
                "donation"),
    "serve": ("tok_s", "chunked_tok_s", "grouped_admit_tok_s",
              "seq_admit_tok_s", "prefix_reuse_tok_s", "prefill_compiles",
              "grouped_prefill_dispatches", "prefix_dedup_ratio",
              "donation"),
    "aedp": ("speedup", "reduction", "tok_s"),
}


def _git(*args):
    try:
        out = subprocess.run(["git", "-C", ROOT, *args],
                             capture_output=True, text=True, timeout=30)
        return out.stdout if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def history(name: str, revs: int = 6):
    """[(label, summary_dict)] oldest→newest for one committed baseline
    (git history of benchmarks/baselines/BENCH_<name>.json), empty when
    git or the file is unavailable."""
    rel = f"benchmarks/baselines/BENCH_{name}.json"
    log = _git("log", "--format=%h", "--", rel)
    if not log:
        return []
    cols = []
    for rev in log.split()[:revs][::-1]:
        text = _git("show", f"{rev}:{rel}")
        if text is None:
            continue
        try:
            cols.append((rev, json.loads(text)))
        except json.JSONDecodeError:
            continue
    return cols


def _fmt(v) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, str):
        return v if len(v) <= 12 else v[:11] + "…"
    if isinstance(v, (int, float)):
        return f"{v:.4g}"
    return "?"


def table(name: str, revs: int = 6) -> str:
    """Markdown-ish trajectory table for one bench, '' when no data."""
    cols = history(name, revs)
    fresh_path = os.path.join(os.getcwd(), f"BENCH_{name}.json")
    fresh = None
    if os.path.exists(fresh_path):
        try:
            with open(fresh_path) as f:
                fresh = json.load(f)
        except (OSError, json.JSONDecodeError):
            fresh = None
    if not cols and fresh is None:
        return ""
    prefixes = KEY_PREFIXES.get(name, ())
    keys = []
    for _, d in cols + ([("fresh", fresh)] if fresh else []):
        for k in d:
            if k not in keys and (not prefixes
                                  or any(k.startswith(p) for p in prefixes)):
                keys.append(k)
    if not keys:
        return ""
    heads = [rev for rev, _ in cols] + (["fresh", "delta"] if fresh else [])
    width = max(len(k) for k in keys)
    lines = [f"== BENCH_{name} trajectory (oldest → newest) ==",
             " " * width + "  " + "  ".join(f"{h:>10}" for h in heads)]
    newest = cols[-1][1] if cols else {}
    for k in sorted(keys):
        row = [_fmt(d.get(k, "-")) for _, d in cols]
        if fresh is not None:
            cur, base = fresh.get(k), newest.get(k)
            row.append(_fmt(cur if cur is not None else "-"))
            if (isinstance(cur, (int, float)) and isinstance(base,
                                                             (int, float))
                    and not isinstance(cur, bool) and base):
                row.append(f"{(cur - base) / abs(base):+.0%}")
            else:
                row.append("new" if base is None and cur is not None
                           else "-")
        lines.append(f"{k:<{width}}  " + "  ".join(f"{c:>10}" for c in row))
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    revs = 6
    if "--revs" in argv:
        i = argv.index("--revs")
        revs = int(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    names = argv
    if not names and os.path.isdir(BASE_DIR):
        names = sorted(
            f[len("BENCH_"):-len(".json")] for f in os.listdir(BASE_DIR)
            if f.startswith("BENCH_") and f.endswith(".json"))
    shown = 0
    for name in names:
        t = table(name, revs)
        if t:
            print(t + "\n")
            shown += 1
    if not shown:
        print("no committed baselines or fresh BENCH_*.json found")
    return 0


if __name__ == "__main__":
    sys.exit(main())
