"""Cross-PR performance trajectory from committed bench baselines.

Every PR that moves performance refreshes ``benchmarks/baselines/
BENCH_*.json``, so the git history of those files IS the repo's
performance trajectory — one column per committing PR. This module
renders it as a table (plus a ``fresh`` column from the current run's
``./BENCH_*.json`` when present, with a delta against the newest
committed column), and is appended to the ``benchmarks/run.py --smoke``
output so every CI bench run shows where the numbers came from, not
just where they are.

``--plot`` additionally renders the same series to a
``BENCH_trajectory.svg`` + ``.png`` pair (``--plot-out`` overrides the
path) — the artifact CI uploads next to the ``BENCH_*.json`` files; the
table never depends on matplotlib being importable.

Standalone::

    python benchmarks/trajectory.py [--revs 6] [--plot] [names...]

Wall-clock caveat: columns come from different machines/runs — the
trajectory shows direction and order of magnitude, not tight ratios
(deterministic counters like dispatch counts ARE exact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASE_DIR = os.path.join(ROOT, "benchmarks", "baselines")

# the headline metrics worth a trajectory row, per bench file (matched
# as prefixes so sweep families stay together without enumerating fills)
KEY_PREFIXES = {
    "latency": ("unicaim_us_ctx", "dense_us_ctx", "unicaim_scan_us_ctx",
                "unicaim_win_us_fill", "unicaim_inplace_us_fill",
                "win_speedup", "inplace_speedup", "speedup_vs_dense",
                "donation"),
    "serve": ("tok_s", "chunked_tok_s", "grouped_admit_tok_s",
              "seq_admit_tok_s", "prefix_reuse_tok_s", "prefill_compiles",
              "grouped_prefill_dispatches", "prefix_dedup_ratio",
              "preemptions", "reservations", "reserved_admits",
              "decode_block_programs", "slo_hi_p99_ttft_s",
              "slo_hi_attainment", "slo_bulk_p99_ttft_s",
              "donation"),
    "aedp": ("speedup", "reduction", "tok_s"),
}


def _git(*args):
    try:
        out = subprocess.run(["git", "-C", ROOT, *args],
                             capture_output=True, text=True, timeout=30)
        return out.stdout if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def history(name: str, revs: int = 6):
    """[(label, summary_dict)] oldest→newest for one committed baseline
    (git history of benchmarks/baselines/BENCH_<name>.json), empty when
    git or the file is unavailable."""
    rel = f"benchmarks/baselines/BENCH_{name}.json"
    log = _git("log", "--format=%h", "--", rel)
    if not log:
        return []
    cols = []
    for rev in log.split()[:revs][::-1]:
        text = _git("show", f"{rev}:{rel}")
        if text is None:
            continue
        try:
            cols.append((rev, json.loads(text)))
        except json.JSONDecodeError:
            continue
    return cols


def _fmt(v) -> str:
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, str):
        return v if len(v) <= 12 else v[:11] + "…"
    if isinstance(v, (int, float)):
        return f"{v:.4g}"
    return "?"


def _load_fresh(name: str):
    """The current run's ./BENCH_<name>.json, None when absent/bad."""
    fresh_path = os.path.join(os.getcwd(), f"BENCH_{name}.json")
    if not os.path.exists(fresh_path):
        return None
    try:
        with open(fresh_path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def table(name: str, revs: int = 6) -> str:
    """Markdown-ish trajectory table for one bench, '' when no data."""
    cols = history(name, revs)
    fresh = _load_fresh(name)
    if not cols and fresh is None:
        return ""
    prefixes = KEY_PREFIXES.get(name, ())
    keys = []
    for _, d in cols + ([("fresh", fresh)] if fresh else []):
        for k in d:
            if k not in keys and (not prefixes
                                  or any(k.startswith(p) for p in prefixes)):
                keys.append(k)
    if not keys:
        return ""
    heads = [rev for rev, _ in cols] + (["fresh", "delta"] if fresh else [])
    width = max(len(k) for k in keys)
    lines = [f"== BENCH_{name} trajectory (oldest → newest) ==",
             " " * width + "  " + "  ".join(f"{h:>10}" for h in heads)]
    newest = cols[-1][1] if cols else {}
    for k in sorted(keys):
        row = [_fmt(d.get(k, "-")) for _, d in cols]
        if fresh is not None:
            cur, base = fresh.get(k), newest.get(k)
            row.append(_fmt(cur if cur is not None else "-"))
            if (isinstance(cur, (int, float)) and isinstance(base,
                                                             (int, float))
                    and not isinstance(cur, bool) and base):
                row.append(f"{(cur - base) / abs(base):+.0%}")
            else:
                row.append("new" if base is None and cur is not None
                           else "-")
        lines.append(f"{k:<{width}}  " + "  ".join(f"{c:>10}" for c in row))
    return "\n".join(lines)


def plot(names, revs: int = 6, out: str = "BENCH_trajectory.svg"):
    """Render the cross-PR series to an SVG + PNG pair (the CI
    artifact): one panel per bench, one line per headline metric over
    the committed-baseline columns (+ the fresh run when present).
    symlog y-axis — the panels mix tok/s in the thousands with counters
    near zero. Returns the written paths; [] when matplotlib or the
    data is unavailable (the table path never depends on it)."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:                          # no plotting backend: skip
        return []
    panels = []
    for name in names:
        cols = history(name, revs)
        fresh = _load_fresh(name)
        if fresh is not None:
            cols = cols + [("fresh", fresh)]
        prefixes = KEY_PREFIXES.get(name, ())
        series = {}
        for i, (_, d) in enumerate(cols):
            for k, v in d.items():
                if (isinstance(v, (int, float)) and not isinstance(v, bool)
                        and (not prefixes
                             or any(k.startswith(p) for p in prefixes))):
                    series.setdefault(k, {})[i] = float(v)
        if series and len(cols) >= 2:
            panels.append((name, [r for r, _ in cols], series))
    if not panels:
        return []
    fig, axes = plt.subplots(1, len(panels),
                             figsize=(5.5 * len(panels), 4.5),
                             squeeze=False)
    for ax, (name, labels, series) in zip(axes[0], panels):
        for k, pts in sorted(series.items()):
            xs = sorted(pts)
            ax.plot(xs, [pts[x] for x in xs], marker="o", ms=3, lw=1,
                    label=k)
        ax.set_yscale("symlog", linthresh=1e-3)
        ax.set_title(f"BENCH_{name}", fontsize=10)
        ax.set_xticks(range(len(labels)))
        ax.set_xticklabels(labels, rotation=45, fontsize=7)
        ax.grid(True, alpha=0.3)
        ax.legend(fontsize=6, loc="best")
    fig.suptitle("cross-PR bench trajectory (committed baselines → fresh)",
                 fontsize=11)
    fig.tight_layout()
    paths = []
    for ext in (".svg", ".png"):
        p = os.path.splitext(out)[0] + ext
        try:
            fig.savefig(p)
            paths.append(p)
        except OSError:
            pass
    plt.close(fig)
    return paths


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    revs = 6
    if "--revs" in argv:
        i = argv.index("--revs")
        revs = int(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    do_plot = "--plot" in argv
    argv = [a for a in argv if a != "--plot"]
    out = "BENCH_trajectory.svg"
    if "--plot-out" in argv:
        i = argv.index("--plot-out")
        out = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
        do_plot = True
    names = argv
    if not names and os.path.isdir(BASE_DIR):
        names = sorted(
            f[len("BENCH_"):-len(".json")] for f in os.listdir(BASE_DIR)
            if f.startswith("BENCH_") and f.endswith(".json"))
    shown = 0
    for name in names:
        t = table(name, revs)
        if t:
            print(t + "\n")
            shown += 1
    if not shown:
        print("no committed baselines or fresh BENCH_*.json found")
    if do_plot:
        paths = plot(names, revs, out)
        print("trajectory plot: " + (", ".join(paths) if paths
                                     else "skipped (no matplotlib/data)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
