"""Fig. 13 mechanism benchmark — attention-mass capture at matched budgets.

Why UniCAIM beats a fixed window (StreamingLLM): its kept set maximises the
accumulated attention mass of the prompt. We measure, on a TRAINED model,
the fraction of dense-prefill attention mass each policy's kept cache
covers (per layer/head, averaged). Deterministic and model-grounded — the
task-level F1 gap in the paper's Fig. 13 is downstream of exactly this
quantity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_trained_model
from repro.core import baselines
from repro.models.transformer import Model

PROMPT = 96


def kept_mass(cfg, params, prune, toks, acc_ref):
    model = Model(cfg, prune)
    _, state = jax.jit(model.prefill)(params, {"tokens": toks})
    pos = np.asarray(state.kv.pos)            # [L, B, Hk, S]
    masses = []
    L, B, Hk, S = pos.shape
    for l in range(L):
        for b in range(B):
            for h in range(Hk):
                kept = pos[l, b, h]
                kept = kept[(kept >= 0) & (kept < PROMPT)]
                a = acc_ref[l, b, h]
                masses.append(a[kept].sum() / max(a.sum(), 1e-9))
    return float(np.mean(masses))


def run():
    cfg, params, src = tiny_trained_model()
    toks = jnp.asarray(src.batch(4242, 4)[:, :PROMPT])
    # reference accumulated attention mass from a dense H2O prefill
    # (exact scores, nothing dropped: budget = full prompt)
    probe = baselines.h2o(heavy=PROMPT, reserve=8, recent=1)
    m = Model(cfg, probe)
    _, state = jax.jit(m.prefill)(params, {"tokens": toks})
    acc = np.asarray(state.kv.acc)            # [L,B,Hk,S]
    pos = np.asarray(state.kv.pos)
    L, B, Hk, S = pos.shape
    acc_by_pos = np.zeros((L, B, Hk, PROMPT))
    for l in range(L):
        for b in range(B):
            for h in range(Hk):
                p = pos[l, b, h]
                ok = p >= 0
                acc_by_pos[l, b, h, p[ok]] = acc[l, b, h, ok]

    for ratio in (0.5, 0.25):
        budget = int(PROMPT * ratio)
        pol = {
            "unicaim": baselines.unicaim(heavy=budget - 8, reserve=8,
                                         select_k=max(8, budget // 4),
                                         sink_tokens=2, recent_window=8),
            "snapkv": baselines.snapkv(heavy=budget - 8, reserve=8,
                                       obs_window=16, recent=8),
            "streaming": baselines.streaming(budget, sinks=2),
        }
        row = {n: kept_mass(cfg, params, p, toks, acc_by_pos)
               for n, p in pol.items()}
        emit(f"needle_mass_r{int(ratio * 100)}", 0.0,
             ";".join(f"{n}_mass={v:.3f}" for n, v in row.items())
             + f";unicaim_vs_streaming={row['unicaim'] / row['streaming']:.2f}x")


if __name__ == "__main__":
    run()
