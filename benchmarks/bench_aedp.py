"""Paper Table II — AEDP (area·energy·delay product) analog on TPU.

The circuit AEDP has no direct TPU meaning; its TPU analog per decode step:
  area   → HBM bytes RESIDENT for the cache (fixed budget vs growing)
  energy → HBM bytes MOVED by the attention step (energy ∝ DRAM traffic)
  delay  → roofline-bound step latency (max of compute/memory terms)
AEDP_analog = resident_bytes × moved_bytes × bound_latency, reported as a
reduction ratio vs the dense-cache baseline at 0/50/80% pruning — the same
sweep as Table II. Also measures real CPU wall time as a sanity proxy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core import baselines
from repro.core.attention import decode_attention
from repro.core.cache import init_cache
from repro.core.pruning import memory_footprint_bytes
from repro.core.quant import mirror_bytes_per_token
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

# paper's setup: 576-token cache (512 heavy + 64 reserved), d=128
B, HK, HQ, D = 4, 4, 4, 128
SEQ = 576


def step_bytes_moved(n_attend: int, n_scored: int, score_bits: int,
                     kv_bytes: int = 2) -> int:
    """HBM bytes one decode step touches in attention."""
    mirror = n_scored * HK * mirror_bytes_per_token(D, score_bits) \
        if n_scored else 0
    exact = 2 * n_attend * HK * D * kv_bytes          # K and V rows
    return mirror + exact


def step_flops(n_attend: int, n_scored: int) -> int:
    return 2 * HQ * D * (n_attend + n_scored)


def run():
    results = {}
    summary = {}
    labels = (("no_prune", 0.0), ("prune50", 0.5)) if common.SMOKE else \
        (("no_prune", 0.0), ("prune50", 0.5), ("prune80", 0.8))
    modes = (("1bit", 1),) if common.SMOKE else (("1bit", 1), ("3bit", 3))
    for label, ratio in labels:
        keep = int(SEQ * (1 - ratio)) or 1
        for mode, bits in modes:
            if label == "no_prune":
                prune = baselines.dense(SEQ)
                n_attend, n_scored = SEQ, 0
                resident = memory_footprint_bytes(SEQ, HK, D, prune)
            else:
                select = max(1, keep // 4)
                prune = baselines.unicaim(
                    heavy=keep - 32, reserve=32, select_k=select,
                    score_bits=bits, sink_tokens=2, recent_window=8)
                n_attend, n_scored = select, keep
                resident = memory_footprint_bytes(SEQ, HK, D, prune)
            moved = step_bytes_moved(n_attend, n_scored,
                                     prune.score_bits)
            delay = max(step_flops(n_attend, n_scored) / PEAK_FLOPS,
                        moved / HBM_BW)
            aedp = resident * moved * delay

            cache = init_cache(B, HK, D, prune.slots, prune, jnp.float32)
            fn = jax.jit(lambda c, q, k, v, p=prune:
                         decode_attention(c, q, k, v, p))
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            q = jax.random.normal(ks[0], (B, HQ, D))
            kn = jax.random.normal(ks[1], (B, HK, D))
            vn = jax.random.normal(ks[2], (B, HK, D))
            # warm the cache
            c = cache
            for i in range(8):
                c, _ = fn(c, q, kn, vn)
            us = time_fn(lambda: fn(c, q, kn, vn))
            fused_note = ""
            if prune.policy == "unicaim":
                # same step through the fused single-pass engine
                pf = dataclasses.replace(prune, fused=True)
                ffn = jax.jit(lambda c, q, k, v, p=pf:
                              decode_attention(c, q, k, v, p))
                cf = init_cache(B, HK, D, pf.slots, pf, jnp.float32)
                for i in range(8):
                    cf, _ = ffn(cf, q, kn, vn)
                us_f = time_fn(lambda: ffn(cf, q, kn, vn))
                fused_note = (f";fused_us={us_f:.1f}"
                              f";fused_speedup={us / us_f:.2f}x")
            results[(label, mode)] = aedp
            base = results.get(("no_prune", "1bit"), aedp)
            emit(f"aedp_{label}_{mode}", us,
                 f"aedp_reduction_vs_dense={base / aedp:.1f}x;"
                 f"resident_B={resident};moved_B={moved};"
                 f"delay_us={delay * 1e6:.3f}" + fused_note)
            summary[f"{label}_{mode}_us"] = us
            summary[f"{label}_{mode}_reduction_vs_dense"] = base / aedp
            if label == "no_prune":
                break   # dense is bit-independent
    # machine-readable trajectory (written to BENCH_aedp.json by
    # `benchmarks/run.py --smoke`; CI compares against the committed copy)
    return summary


if __name__ == "__main__":
    run()
