"""Paper Fig. 11 — energy vs sequence length. On TPU, decode energy is
dominated by HBM traffic; we report bytes moved per decode step (dense vs
UniCAIM) across input lengths (output=64) and output lengths (input=2048),
mirroring the paper's 5.3×→27× energy-efficiency trend."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.quant import mirror_bytes_per_token

HK, HQ, D = 8, 32, 128
L = 32                       # layers


def step_bytes(policy: str, ctx: int, budget: int = 576,
               select_k: int = 64, bits: int = 3) -> int:
    """Per-decode-step HBM bytes for attention across L layers."""
    if policy == "dense":
        n = ctx
        return L * 2 * n * HK * D * 2                 # read all K and V
    n = min(ctx, budget)
    mirror = L * n * HK * mirror_bytes_per_token(D, bits)
    exact = L * 2 * select_k * HK * D * 2
    return mirror + exact


def run():
    for n_in in (512, 1024, 2048, 4096, 8192, 16384, 32768):
        ctx = n_in + 64
        dense_b = step_bytes("dense", ctx)
        uni_b = step_bytes("unicaim", ctx)
        emit(f"energy_in{n_in}", 0.0,
             f"dense_B={dense_b};unicaim_B={uni_b};"
             f"energy_reduction={dense_b / uni_b:.1f}x")
    for n_out in (64, 256, 1024, 4096, 16384):
        ctx = 2048 + n_out
        dense_b = step_bytes("dense", ctx)
        uni_b = step_bytes("unicaim", ctx)
        emit(f"energy_out{n_out}", 0.0,
             f"dense_B={dense_b};unicaim_B={uni_b};"
             f"energy_reduction={dense_b / uni_b:.1f}x")


if __name__ == "__main__":
    run()
