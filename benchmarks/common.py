"""Shared benchmark utilities: timing, CSV emission, tiny trained model."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# CI smoke mode: benches read this to shrink their sweep (set by
# `benchmarks/run.py --smoke`).
SMOKE = False


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


class Timing(float):
    """Min wall-time (µs) that also carries the run's p50.

    Behaves exactly like the float minimum everywhere (comparisons,
    arithmetic, json serialization), so existing callers keep their
    min-based semantics; `.p50` exposes the median of the same samples
    so benches can record a `<name>_p50` sibling row. compare_bench
    uses the p50/min ratio to flag noisy runs whose ratios should not
    be trusted."""

    def __new__(cls, samples):
        ts = np.asarray(samples, dtype=np.float64)
        self = super().__new__(cls, float(np.min(ts)))
        self.p50 = float(np.median(ts))
        return self


def time_fn(fn, *args, warmup=2, iters=7):
    """Min wall-time (µs) of a jitted callable (a `Timing` float; its
    `.p50` attribute holds the median of the same samples).

    Min, not median: shared CI runners carry multi-ms scheduling noise
    that inflates medians by 2-3x run to run (interleaved profiling of
    identical programs confirmed it), while the minimum tracks the
    actual compute floor. Cross-engine ratios from medians here once
    recorded a spurious 1.3x "regression" (see BENCH_latency.json
    history around the fused engine)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return Timing(ts)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


_MODEL_CACHE = {}


def tiny_trained_model(steps: int = 80, seed: int = 0):
    """A small LM trained on synthetic data — shared across accuracy
    benchmarks so policies are compared on a model with real structure."""
    key = ("m", steps, seed)
    if key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    from repro.configs.base import get_config, reduced
    from repro.core import baselines
    from repro.data.pipeline import SyntheticSource
    from repro.launch.train import init_train_state, make_train_step
    from repro.models.transformer import Model
    from repro.optim import adamw

    cfg = reduced(get_config("longchat-7b"), num_layers=3, d_model=96,
                  n_heads=6, n_kv_heads=6, head_dim=16, d_ff=192,
                  vocab_size=512)
    prune = baselines.dense(512)
    model = Model(cfg, prune)
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, opt_cfg, total_steps=steps,
                                   peak_lr=3e-3, warmup=10))
    src = SyntheticSource(cfg.vocab_size, 128, seed=seed)
    for i in range(steps):
        state, m = step(state, {"tokens": jnp.asarray(src.batch(i, 8))})
    _MODEL_CACHE[key] = (cfg, state.params, src)
    return _MODEL_CACHE[key]
