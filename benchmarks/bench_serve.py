"""Serving-throughput benchmark — lane-granular continuous batching vs the
old admit-all-lanes loop, on the same staggered request set.

Rows (CSV: name,us_per_call,derived):
  serve_static_<tag>        wall µs; derived useful-token tok/s
  serve_continuous_<tag>    wall µs; derived tok/s, mean latency, occupancy
  serve_speedup_<tag>       continuous-vs-static useful-token throughput
  serve_load_<tag>_r<rate>  offered-load sweep (requests arrive rate/s)

'Useful tokens' counts each request's own `max_new`: the old loop forces
every lane in a group to the group's max budget over equally padded
prompts, so its excess generated tokens are waste, not throughput. Both
engines run the same jitted scanned decode block — the comparison isolates
the *scheduling* win (lane recycling + right-sized prefills), not kernel
differences.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import ServeLoop
from repro.models.transformer import Model

BLOCK = 8


def _request_set(vocab, n, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, int(lens[i % len(lens)])),
             int(budgets[i % len(budgets)])) for i in range(n)]


def _run_static(model, params, reqs, lanes):
    """The old admit-all-lanes loop: requests grouped `lanes` at a time,
    prompts right-padded to the group's max length, every lane decoding the
    group's max budget; the next group waits for the slowest lane."""
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK)
    useful = 0
    t0 = time.perf_counter()
    for g in range(0, len(reqs), lanes):
        group = reqs[g:g + lanes]
        width = max(len(p) for p, _ in group)
        prompts = np.zeros((lanes, width), np.int64)
        for i in range(lanes):
            p = group[i % len(group)][0]       # short groups: reuse prompts
            prompts[i, :len(p)] = p
        loop.max_new = max(mn for _, mn in group)
        loop.admit(prompts)
        while loop.step_block():
            pass
        useful += sum(mn for _, mn in group)
    return useful, time.perf_counter() - t0


def _run_continuous(model, params, reqs, lanes, rate=None):
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK)
    for i, (prompt, mn) in enumerate(reqs):
        loop.submit(prompt, max_new=mn,
                    arrival=0.0 if rate is None else i / rate)
    t0 = time.perf_counter()
    loop.run()
    return loop.aggregate(), time.perf_counter() - t0


def run():
    cfg = reduced(get_config("granite-3-2b"))
    lanes = 2 if common.SMOKE else 4
    n = 8 if common.SMOKE else 16
    lens = (24, 48) if common.SMOKE else (32, 64, 96)
    budgets = (6, 40) if common.SMOKE else (8, 16, 48)
    uni = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                            sink_tokens=2, recent_window=8)
    policies = [("unicaim", uni),
                ("unicaim_fused", dataclasses.replace(uni, fused=True))]
    if not common.SMOKE:
        policies += [
            ("h2o", baselines.h2o(heavy=48, reserve=16, recent=8)),
            ("streaming", baselines.streaming(64, sinks=2)),
            ("dense", baselines.dense(max(lens) + max(budgets))),
        ]
    reqs = _request_set(cfg.vocab_size, n, lens, budgets)
    params = None
    for tag, prune in policies:
        model = Model(cfg, prune)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        # untimed warmup pass on the same shapes (compiles amortize)
        _run_static(model, params, reqs, lanes)
        _run_continuous(model, params, reqs, lanes)

        # best-of-2: shared-CPU wall times are noisy; ratios need the floor
        (useful, dt_s), (_, dt_s2) = (_run_static(model, params, reqs, lanes)
                                      for _ in range(2))
        dt_s = min(dt_s, dt_s2)
        emit(f"serve_static_{tag}", dt_s * 1e6,
             f"tok_s={useful / dt_s:.1f}")
        (agg, dt_c), (_, dt_c2) = (_run_continuous(model, params, reqs, lanes)
                                   for _ in range(2))
        dt_c = min(dt_c, dt_c2)
        emit(f"serve_continuous_{tag}", dt_c * 1e6,
             f"tok_s={agg['tokens'] / dt_c:.1f};"
             f"mean_latency_s={agg['mean_latency_s']:.3f};"
             f"occ={agg['mean_occupancy']:.2f}")
        emit(f"serve_speedup_{tag}", 0.0,
             f"continuous_vs_static={dt_s / dt_c:.2f}x")
        if not common.SMOKE and tag == "unicaim":
            for rate in (20.0, 5.0):
                agg, _ = _run_continuous(model, params, reqs, lanes,
                                         rate=rate)
                emit(f"serve_load_{tag}_r{rate:g}", 0.0,
                     f"tok_s={agg['tokens_per_s']:.1f};"
                     f"mean_latency_s={agg['mean_latency_s']:.3f}")


if __name__ == "__main__":
    run()
