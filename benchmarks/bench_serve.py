"""Serving-throughput benchmark — lane-granular continuous batching vs the
old admit-all-lanes loop, on the same staggered request set.

Rows (CSV: name,us_per_call,derived):
  serve_static_<tag>        wall µs; derived useful-token tok/s
  serve_continuous_<tag>    wall µs; derived tok/s, mean latency, occupancy,
                            p99 ttft, prefill compile (shape) count
  serve_speedup_<tag>       continuous-vs-static useful-token throughput
  serve_exactlen_<tag>      legacy exact-length prefills (compile-count
                            comparison row: one program per distinct length)
  serve_chunked_<tag>       Sarathi-style sliced-prefill admission
  serve_load_<tag>_r<rate>  offered-load sweep (requests arrive rate/s)
  serve_admit_seq_<tag>     bursty same-bucket arrivals, sequential
                            admission (one prefill + splice per request)
  serve_admit_grouped_<tag> same burst, grouped admission (one batched
                            prefill + one multi-lane splice per group) —
                            the dispatch-count rows for the ISSUE gate
  serve_prefix_noreuse_<tag> shared-system-prompt traffic (one 48-token
                            prefix, distinct suffixes), prefix cache off
  serve_prefix_reuse_<tag>  same traffic with the radix-trie prefix cache:
                            suffix-only prefills after the first request —
                            hit-rate/dedup/TTFT rows for the ISSUE gate
  serve_priority_<tag>      mixed-priority burst (bulk priority-0 saturating
                            every lane + a priority-5 latency burst):
                            preemption/reservation/one-program counters —
                            deterministic count-class rows for CI
  serve_slo_{hi,bulk}_<tag> per-class p99/mean TTFT; the burst row adds
                            SLO attainment against the bulk-p99 TTFT
  serve_fault_clean_<tag>   fault-sweep reference: the identical workload
                            with no chaos config — its wall row is the
                            sentinel's clean-path overhead (warn-only)
  serve_fault_injected_<tag> same workload under 1%-per-(step,lane) seeded
                            logit corruption: every affected request must
                            recover via quarantine+retry token-identically
                            (identical=1 share row) — quarantine/retry
                            counters are deterministic count-class rows
  serve_fault_flood_<tag>   chaos queue flood against a bounded queue
                            (max_queue): served/rejected/shed split is
                            deterministic; tok/s + p99 TTFT of the
                            admitted population

'Useful tokens' counts each request's own `max_new`: the old loop forces
every lane in a group to the group's max budget over equally padded
prompts, so its excess generated tokens are waste, not throughput. Both
engines run the same jitted scanned decode block — the comparison isolates
the *scheduling* win (lane recycling + right-sized prefills), not kernel
differences.

`run()` returns a machine-readable summary (tok/s, p50/p99 ttft, prefill
compile count) that `benchmarks/run.py --smoke` writes to BENCH_serve.json.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import Request, ServeLoop
from repro.models.transformer import Model
from repro.runtime.chaos import ChaosConfig, flood

BLOCK = 8


def _request_set(vocab, n, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, vocab, int(lens[i % len(lens)])),
             int(budgets[i % len(budgets)])) for i in range(n)]


def _run_static(model, params, reqs, lanes):
    """The old admit-all-lanes loop: requests grouped `lanes` at a time,
    prompts right-padded to the group's max length, every lane decoding the
    group's max budget; the next group waits for the slowest lane."""
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK)
    useful = 0
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        # this row IS the deprecated legacy loop — that's what it measures
        warnings.simplefilter("ignore", DeprecationWarning)
        for g in range(0, len(reqs), lanes):
            group = reqs[g:g + lanes]
            width = max(len(p) for p, _ in group)
            prompts = np.zeros((lanes, width), np.int64)
            for i in range(lanes):
                p = group[i % len(group)][0]   # short groups: reuse prompts
                prompts[i, :len(p)] = p
            loop.max_new = max(mn for _, mn in group)
            loop.admit(prompts)
            while loop.step_block():
                pass
            useful += sum(mn for _, mn in group)
    return useful, time.perf_counter() - t0


def _run_continuous(model, params, reqs, lanes, rate=None, buckets="auto",
                    chunk_prefill=0, group_admit=True,
                    prefix_cache_bytes=0):
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK,
                     buckets=buckets, chunk_prefill=chunk_prefill,
                     group_admit=group_admit,
                     prefix_cache_bytes=prefix_cache_bytes)
    for i, (prompt, mn) in enumerate(reqs):
        loop.submit(Request(prompt=prompt, max_new=mn,
                            arrival=0.0 if rate is None else i / rate))
    t0 = time.perf_counter()
    loop.run()
    agg = loop.aggregate()
    agg["prefill_programs"] = float(loop.prefill_programs()["loop_shapes"])
    return agg, time.perf_counter() - t0


def _run_priority(model, params, vocab, lanes, seed=7):
    """Mixed-priority SLO scenario: bulk (priority 0, long budgets)
    saturates every lane and queues a second wave, then a
    latency-sensitive burst (priority 5, short budgets) lands mid-decode.
    Each burst request must preempt a bulk lane (exactly `lanes`
    preemptions — deterministic: scheduling decisions depend on queue
    counts, never the wall clock), and drain-aware reservation pre-groups
    the requeued bulk work. Returns (per-class stats, loop, wall)."""
    rng = np.random.default_rng(seed)
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK,
                     reserve_blocks=2)
    for _ in range(2 * lanes):
        loop.submit(Request(prompt=rng.integers(0, vocab, 24), max_new=32,
                            priority=0))
    t0 = time.perf_counter()
    loop.schedule()                    # bulk saturates the lanes...
    loop._step_block()                 # ...and decodes one block
    for _ in range(lanes):             # burst arrives while all lanes busy
        loop.submit(Request(prompt=rng.integers(0, vocab, 24), max_new=4,
                            priority=5))
    stats = loop.run()
    dt = time.perf_counter() - t0
    by_class = {}
    for s in stats:
        by_class.setdefault(s.priority, []).append(s)
    return by_class, loop, dt


def _run_fault(model, params, reqs, lanes, chaos=None, max_queue=0):
    """One fault-sweep leg: the Request-handle API (token streams must be
    comparable across legs), optional chaos injection + queue bound."""
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK,
                     chaos=chaos, max_queue=max_queue)
    hs = [loop.submit(Request(prompt=p, max_new=mn)) for p, mn in reqs]
    t0 = time.perf_counter()
    loop.run()
    return hs, loop, time.perf_counter() - t0


def _done_row(loop, dt):
    """tok/s + p99 TTFT over the requests that completed "done"."""
    done = [s for s in loop.completed if s.outcome == "done"]
    toks = sum(len(s.tokens) for s in done)
    ttfts = np.asarray([s.ttft for s in done] or [0.0])
    return toks / dt, float(np.percentile(ttfts, 99)), len(done)


def _slo_row(stats, slo_s):
    """p99/mean TTFT + SLO attainment for one priority class."""
    ttfts = np.asarray([s.ttft for s in stats])
    return {"requests": float(len(stats)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
            "mean_ttft_s": float(ttfts.mean()),
            "attainment": float((ttfts <= slo_s).mean())}


def _sharded_trace(vocab, n, seed=11):
    """Saturation-scale arrival trace: staggered variable-length prompts,
    mixed budgets, a 1-in-7 high-priority burst class riding on bulk."""
    rng = np.random.default_rng(seed)
    lens = (9, 17, 24, 31, 40, 47, 63, 64)
    return [dict(prompt=rng.integers(0, vocab, int(lens[i % len(lens)])),
                 max_new=int(3 + i % 10),
                 priority=5 if i % 7 == 0 else 0)
            for i in range(n)]


def _run_trace(model, params, trace, lanes, mesh, rate=None):
    """Replay one arrival trace; returns (streams, agg, loop, wall)."""
    loop = ServeLoop(model, params, lanes=lanes, eos=-1, block=BLOCK,
                     mesh=mesh)
    hs = [loop.submit(Request(arrival=0.0 if rate is None else i / rate,
                              **kw))
          for i, kw in enumerate(trace)]
    t0 = time.perf_counter()
    loop.run()
    return ([h.tokens for h in hs], loop.aggregate(), loop,
            time.perf_counter() - t0)


def _shared_prefix_set(vocab, n, shared=112, suffix=16, budget=6, seed=5):
    """One shared system prompt + distinct per-request suffixes: the
    production shape prefix caching targets. 128-token prompts with
    chunk_prefill=16 give 8 slices cold vs 1 slice on a hit."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, vocab, shared)
    return [(np.concatenate([head, rng.integers(0, vocab, suffix)]), budget)
            for _ in range(n)]


def run():
    cfg = reduced(get_config("granite-3-2b"))
    lanes = 2 if common.SMOKE else 4
    n = 8 if common.SMOKE else 16
    # >= 8 distinct prompt lengths: the compile-bound rows need realistic
    # mixed traffic, not two widths
    lens = ((9, 17, 24, 31, 40, 47, 48, 63) if common.SMOKE
            else (9, 17, 24, 31, 40, 47, 63, 64, 81, 96))
    budgets = (6, 40) if common.SMOKE else (8, 16, 48)
    uni = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                            sink_tokens=2, recent_window=8)
    policies = [("unicaim", uni),
                ("unicaim_fused", dataclasses.replace(uni, fused=True))]
    if not common.SMOKE:
        policies += [
            ("h2o", baselines.h2o(heavy=48, reserve=16, recent=8)),
            ("streaming", baselines.streaming(64, sinks=2)),
            ("dense", baselines.dense(max(lens) + max(budgets))),
        ]
    reqs = _request_set(cfg.vocab_size, n, lens, budgets)
    params = None
    summary = {}
    for tag, prune in policies:
        model = Model(cfg, prune)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        # untimed warmup pass on the same shapes (compiles amortize)
        _run_static(model, params, reqs, lanes)
        _run_continuous(model, params, reqs, lanes)

        # best-of-2: shared-CPU wall times are noisy; ratios need the floor
        (useful, dt_s), (_, dt_s2) = (_run_static(model, params, reqs, lanes)
                                      for _ in range(2))
        dt_s = min(dt_s, dt_s2)
        emit(f"serve_static_{tag}", dt_s * 1e6,
             f"tok_s={useful / dt_s:.1f}")
        (agg, dt_c), (_, dt_c2) = (_run_continuous(model, params, reqs, lanes)
                                   for _ in range(2))
        dt_c = min(dt_c, dt_c2)
        emit(f"serve_continuous_{tag}", dt_c * 1e6,
             f"tok_s={agg['tokens'] / dt_c:.1f};"
             f"mean_latency_s={agg['mean_latency_s']:.3f};"
             f"occ={agg['mean_occupancy']:.2f};"
             f"p99_ttft_s={agg['p99_ttft_s']:.3f};"
             f"prefill_compiles={agg['prefill_programs']:.0f}")
        emit(f"serve_speedup_{tag}", 0.0,
             f"continuous_vs_static={dt_s / dt_c:.2f}x")
        if tag == "unicaim":
            summary = {
                "donation": agg["donation"],
                "tok_s": agg["tokens"] / dt_c,
                "p50_ttft_s": agg["p50_ttft_s"],
                "p99_ttft_s": agg["p99_ttft_s"],
                "prefill_compiles": agg["prefill_programs"],
                "requests": agg["requests"],
                "distinct_prompt_lens": float(len(set(lens))),
            }
            # compile-count comparison: legacy exact-length prefills trace
            # one program per distinct prompt length
            agg_e, dt_e = _run_continuous(model, params, reqs, lanes,
                                          buckets=None)
            emit(f"serve_exactlen_{tag}", dt_e * 1e6,
                 f"tok_s={agg_e['tokens'] / dt_e:.1f};"
                 f"p99_ttft_s={agg_e['p99_ttft_s']:.3f};"
                 f"prefill_compiles={agg_e['prefill_programs']:.0f}")
            summary["prefill_compiles_exactlen"] = agg_e["prefill_programs"]
            # Sarathi-style sliced admission (prefill/decode interleaving)
            _run_continuous(model, params, reqs, lanes, chunk_prefill=16)
            agg_c, dt_ch = _run_continuous(model, params, reqs, lanes,
                                           chunk_prefill=16)
            emit(f"serve_chunked_{tag}", dt_ch * 1e6,
                 f"tok_s={agg_c['tokens'] / dt_ch:.1f};"
                 f"mean_latency_s={agg_c['mean_latency_s']:.3f};"
                 f"p99_ttft_s={agg_c['p99_ttft_s']:.3f};"
                 f"prefill_compiles={agg_c['prefill_programs']:.0f}")
            summary["chunked_tok_s"] = agg_c["tokens"] / dt_ch
            summary["chunked_p99_ttft_s"] = agg_c["p99_ttft_s"]
            # grouped vs sequential admission on a bursty same-bucket
            # arrival set (every prompt pads to one bucket, all arrive at
            # t=0): grouped admits lane-count-sized groups with ONE
            # batched prefill + ONE multi-lane splice each, so it must
            # show fewer prefill dispatches at >= the sequential tok/s
            # (the ISSUE acceptance row). Equal budgets keep the pairing
            # deterministic; best-of-3 because shared-CPU walls are noisy.
            # many short-budget requests keep admission (the thing being
            # measured) a large fraction of the wall next to the decode
            # blocks. Shared-CPU noise spikes last longer than one run,
            # so the two modes are timed in ALTERNATING back-to-back
            # pairs (a contention window hits both) and each side takes
            # its best-of-6 floor — the least noise-sensitive estimator
            # under one-sided contention noise.
            burst = _request_set(cfg.vocab_size, max(16, 4 * lanes),
                                 (33, 40, 37, 47), (4,), seed=3)
            for ga in (False, True):
                _run_continuous(model, params, burst, lanes, group_admit=ga)
            runs_s, runs_g = [], []
            for _ in range(6):
                runs_s.append(_run_continuous(model, params, burst, lanes,
                                              group_admit=False))
                runs_g.append(_run_continuous(model, params, burst, lanes,
                                              group_admit=True))
            agg_s, dt_sq = min(runs_s, key=lambda r: r[1])
            agg_g, dt_g = min(runs_g, key=lambda r: r[1])
            emit(f"serve_admit_seq_{tag}", dt_sq * 1e6,
                 f"tok_s={agg_s['tokens'] / dt_sq:.1f};"
                 f"prefill_dispatches={agg_s['prefill_dispatches']:.0f};"
                 f"admit_dispatches={agg_s['admit_dispatches']:.0f}")
            emit(f"serve_admit_grouped_{tag}", dt_g * 1e6,
                 f"tok_s={agg_g['tokens'] / dt_g:.1f};"
                 f"prefill_dispatches={agg_g['prefill_dispatches']:.0f};"
                 f"admit_dispatches={agg_g['admit_dispatches']:.0f};"
                 f"grouped_requests={agg_g['grouped_requests']:.0f};"
                 f"vs_sequential={dt_sq / dt_g:.2f}x")
            summary.update({
                "burst_requests": float(len(burst)),
                "seq_admit_tok_s": agg_s["tokens"] / dt_sq,
                "grouped_admit_tok_s": agg_g["tokens"] / dt_g,
                "seq_prefill_dispatches": agg_s["prefill_dispatches"],
                "grouped_prefill_dispatches": agg_g["prefill_dispatches"],
                "seq_admit_dispatches": agg_s["admit_dispatches"],
                "grouped_admit_dispatches": agg_g["admit_dispatches"],
                "grouped_requests": agg_g["grouped_requests"],
            })
            # shared-system-prompt traffic: one 48-token prefix, distinct
            # 16-token suffixes, sliced admission (C=16). With the radix
            # trie every request after the first resumes from the cached
            # prefix rows — 1 suffix slice instead of 4 — which is pure
            # admission-latency removal, so p50 TTFT must drop. Timed in
            # alternating pairs, best-of-4 floors (shared-CPU noise hits
            # both sides of a pair).
            shared = _shared_prefix_set(cfg.vocab_size,
                                        8 if common.SMOKE else 16)
            for pcb in (0, 64 << 20):
                _run_continuous(model, params, shared, lanes,
                                chunk_prefill=16, prefix_cache_bytes=pcb)
            runs_n, runs_r = [], []
            for _ in range(4):
                runs_n.append(_run_continuous(model, params, shared, lanes,
                                              chunk_prefill=16))
                runs_r.append(_run_continuous(
                    model, params, shared, lanes, chunk_prefill=16,
                    prefix_cache_bytes=64 << 20))
            agg_n, dt_n = min(runs_n, key=lambda r: r[1])
            agg_r, dt_r = min(runs_r, key=lambda r: r[1])
            emit(f"serve_prefix_noreuse_{tag}", dt_n * 1e6,
                 f"tok_s={agg_n['tokens'] / dt_n:.1f};"
                 f"p50_ttft_s={agg_n['p50_ttft_s']:.3f};"
                 f"chunk_dispatches={agg_n['chunk_dispatches']:.0f}")
            emit(f"serve_prefix_reuse_{tag}", dt_r * 1e6,
                 f"tok_s={agg_r['tokens'] / dt_r:.1f};"
                 f"p50_ttft_s={agg_r['p50_ttft_s']:.3f};"
                 f"chunk_dispatches={agg_r['chunk_dispatches']:.0f};"
                 f"prefix_hit_rate={agg_r['prefix_hit_rate']:.2f};"
                 f"prefix_dedup_ratio={agg_r['prefix_dedup_ratio']:.2f};"
                 f"prefix_copies={agg_r['prefix_copies']:.0f};"
                 f"ttft_vs_noreuse={agg_n['p50_ttft_s'] / max(agg_r['p50_ttft_s'], 1e-9):.2f}x")
            # mixed-priority SLO scenario: a latency-sensitive burst
            # preempts bulk lanes; per-class p99 TTFT + attainment of
            # the burst against the bulk-median SLO, preemption and
            # reservation counters (deterministic — count-class in CI)
            _run_priority(model, params, cfg.vocab_size, lanes)  # warmup
            by_class, ploop, dt_p = _run_priority(model, params,
                                                  cfg.vocab_size, lanes)
            # SLO: the burst must beat the bulk TAIL — despite arriving
            # into a saturated engine, every preempting request gets its
            # first token before the slowest bulk request got its own
            bulk_ttfts = np.asarray([s.ttft for s in by_class[0]])
            slo_s = float(np.percentile(bulk_ttfts, 99))
            hi = _slo_row(by_class[5], slo_s)
            bulk = _slo_row(by_class[0], slo_s)
            emit(f"serve_priority_{tag}", dt_p * 1e6,
                 f"preemptions={ploop.counters['preemptions']:.0f};"
                 f"reservations={ploop.counters['reservations']:.0f};"
                 f"reserved_admits={ploop.counters['reserved_admits']:.0f};"
                 f"block_programs="
                 f"{ploop.counters['decode_block_programs']:.0f}")
            emit(f"serve_slo_hi_{tag}", 0.0,
                 f"p99_ttft_s={hi['p99_ttft_s']:.4f};"
                 f"mean_ttft_s={hi['mean_ttft_s']:.4f};"
                 f"attainment={hi['attainment']:.2f};"
                 f"requests={hi['requests']:.0f}")
            emit(f"serve_slo_bulk_{tag}", 0.0,
                 f"p99_ttft_s={bulk['p99_ttft_s']:.4f};"
                 f"mean_ttft_s={bulk['mean_ttft_s']:.4f};"
                 f"requests={bulk['requests']:.0f}")
            summary.update({
                "preemptions": float(ploop.counters["preemptions"]),
                "reservations": float(ploop.counters["reservations"]),
                "reserved_admits": float(
                    ploop.counters["reserved_admits"]),
                "decode_block_programs": float(
                    ploop.counters["decode_block_programs"]),
                "slo_hi_p99_ttft_s": hi["p99_ttft_s"],
                "slo_hi_attainment": hi["attainment"],
                "slo_bulk_p99_ttft_s": bulk["p99_ttft_s"],
                "slo_hi_requests": hi["requests"],
                "slo_bulk_requests": bulk["requests"],
            })
            # fault sweep: one workload served three ways — clean (the
            # sentinel's all-clean lax.cond path; its wall row is the
            # clean-path overhead, warn-only), under seeded 1%-per-
            # (step, lane) logit corruption (every affected request must
            # recover via quarantine+retry with the clean run's exact
            # stream — deterministic counters, count-class in CI), and
            # as a chaos queue flood against a bounded queue (the
            # served/rejected/shed split is a pure function of the
            # submission sequence).
            freqs = _request_set(cfg.vocab_size, max(12, 3 * lanes),
                                 (17, 24, 33), (8, 12), seed=9)
            inj = ChaosConfig(seed=13, logit_fault_rate=0.01)
            for c in (None, inj):
                _run_fault(model, params, freqs, lanes, chaos=c)  # warmup
            hs_cl, loop_cl, dt_cl = _run_fault(model, params, freqs, lanes)
            hs_in, loop_in, dt_in = _run_fault(model, params, freqs, lanes,
                                               chaos=inj)
            ident = float([h.tokens for h in hs_in]
                          == [h.tokens for h in hs_cl])
            tok_cl, p99_cl, _ = _done_row(loop_cl, dt_cl)
            tok_in, p99_in, _ = _done_row(loop_in, dt_in)
            emit(f"serve_fault_clean_{tag}", dt_cl * 1e6,
                 f"tok_s={tok_cl:.1f};p99_ttft_s={p99_cl:.3f}")
            emit(f"serve_fault_injected_{tag}", dt_in * 1e6,
                 f"tok_s={tok_in:.1f};p99_ttft_s={p99_in:.3f};"
                 f"identical={ident:.0f};"
                 f"quarantined={loop_in.counters['quarantined_lanes']:.0f};"
                 f"retried={loop_in.counters['retried_requests']:.0f};"
                 f"failed={loop_in.counters['failed_requests']:.0f}")
            fl = [(np.asarray(kw["prompt"]), kw["max_new"]) for kw in
                  flood(cfg.vocab_size, 6 * lanes, length=24, max_new=8,
                        seed=21)]
            _run_fault(model, params, fl, lanes, max_queue=2 * lanes)
            hs_f, loop_f, dt_f = _run_fault(model, params, fl, lanes,
                                            max_queue=2 * lanes)
            tok_f, p99_f, served = _done_row(loop_f, dt_f)
            emit(f"serve_fault_flood_{tag}", dt_f * 1e6,
                 f"tok_s={tok_f:.1f};p99_ttft_s={p99_f:.3f};"
                 f"served={served:.0f};"
                 f"rejected={loop_f.counters['rejected_requests']:.0f};"
                 f"shed={loop_f.counters['shed_requests']:.0f}")
            summary.update({
                "fault_requests": float(len(freqs)),
                "fault_clean_tok_s": tok_cl,
                "fault_injected_tok_s": tok_in,
                "fault_replay_identical": ident,
                "fault_quarantined": float(
                    loop_in.counters["quarantined_lanes"]),
                "fault_retried": float(
                    loop_in.counters["retried_requests"]),
                "fault_clean_p99_ttft_s": p99_cl,
                "fault_injected_p99_ttft_s": p99_in,
                "flood_requests": float(len(fl)),
                "flood_served": float(served),
                "flood_rejected": float(
                    loop_f.counters["rejected_requests"]),
                "flood_p99_ttft_s": p99_f,
            })
            summary.update({
                "prefix_requests": float(len(shared)),
                "prefix_hit_rate": agg_r["prefix_hit_rate"],
                "prefix_dedup_ratio": agg_r["prefix_dedup_ratio"],
                "prefix_copies": agg_r["prefix_copies"],
                "prefix_tokens_reused": agg_r["prefix_tokens_reused"],
                "prefix_reuse_p50_ttft_s": agg_r["p50_ttft_s"],
                "prefix_noreuse_p50_ttft_s": agg_n["p50_ttft_s"],
                "prefix_reuse_chunk_dispatches": agg_r["chunk_dispatches"],
                "prefix_noreuse_chunk_dispatches": agg_n["chunk_dispatches"],
                "prefix_reuse_tok_s": agg_r["tokens"] / dt_r,
            })
        if not common.SMOKE and tag == "unicaim":
            for rate in (20.0, 5.0):
                agg, _ = _run_continuous(model, params, reqs, lanes,
                                         rate=rate)
                emit(f"serve_load_{tag}_r{rate:g}", 0.0,
                     f"tok_s={agg['tokens_per_s']:.1f};"
                     f"mean_latency_s={agg['mean_latency_s']:.3f}")

    # -- data-sharded lane-parallel serving (mesh over the data axis) ---------
    # CI forces devices on CPU (XLA_FLAGS=--xla_force_host_platform_
    # device_count=8); on one device the section is skipped. Lanes scale
    # with the device count, so the per-dispatch decode throughput — the
    # device-count-invariant measure of what sharding buys when wall
    # clock can't scale on forced host devices — must scale with it.
    ndev = len(jax.devices())
    if ndev >= 2:
        from repro.launch.mesh import make_serve_mesh
        tag = "unicaim"
        model = Model(cfg, uni)
        mesh = make_serve_mesh()
        sh_lanes = lanes * ndev
        n_s = 64 if common.SMOKE else 2048
        trace = _sharded_trace(cfg.vocab_size, n_s)
        warm = trace[:min(len(trace), 4 * sh_lanes)]
        for ln, ms in ((sh_lanes, mesh), (sh_lanes, None), (lanes, None)):
            _run_trace(model, params, warm, ln, ms)

        toks_m, agg_m, loop_m, dt_m = _run_trace(model, params, trace,
                                                 sh_lanes, mesh)
        # token-identity replay: same trace, same lanes, no mesh — layout
        # must never change arithmetic (greedy bitwise, sampled per seed)
        toks_1, _, _, _ = _run_trace(model, params, trace, sh_lanes, None)
        identical = float(toks_m == toks_1)
        # 1-device reference at the unscaled lane count: the scaling row
        # compares tokens landed per decode-block dispatch at saturation
        _, agg_b, loop_b, dt_b = _run_trace(model, params, trace, lanes,
                                            None)
        tpd_m = agg_m["tokens_per_dispatch"]
        tpd_b = agg_b["tokens"] / max(loop_b.counters["decode_blocks"], 1)
        scaling = tpd_m / tpd_b
        by_class = {}
        for s in loop_m.completed:
            by_class.setdefault(s.priority, []).append(s)
        hi_p99 = float(np.percentile([s.ttft for s in by_class[5]], 99))
        bulk_p99 = float(np.percentile([s.ttft for s in by_class[0]], 99))

        emit(f"serve_sharded_{ndev}dev_{tag}", dt_m * 1e6,
             f"tok_s={agg_m['tokens'] / dt_m:.1f};"
             f"tokens_per_dispatch={tpd_m:.1f};"
             f"scaling_vs_1dev={scaling:.2f}x;"
             f"identical={identical:.0f}")
        emit(f"serve_sharded_pershard_{tag}", 0.0,
             ";".join(f"shard{i}_tok_s={agg_m[f'shard{i}_tok_s']:.1f}"
                      for i in range(ndev)))
        emit(f"serve_sharded_slo_{tag}", 0.0,
             f"hi_p99_ttft_s={hi_p99:.4f};bulk_p99_ttft_s={bulk_p99:.4f};"
             f"requests={float(n_s):.0f}")
        if not common.SMOKE:
            # offered-load sweep to saturation (arrival-staggered)
            for rate in (50.0, 200.0):
                _, agg_l, _, dt_l = _run_trace(model, params, trace,
                                               sh_lanes, mesh, rate=rate)
                emit(f"serve_sharded_load_{tag}_r{rate:g}", dt_l * 1e6,
                     f"tok_s={agg_l['tokens'] / dt_l:.1f}")
        summary.update({
            "shards": float(ndev),
            "sharded_lanes": float(sh_lanes),
            "sharded_requests": float(n_s),
            "sharded_agg_tok_s": agg_m["tokens"] / dt_m,
            "sharded_tokens_per_dispatch": tpd_m,
            "base_tokens_per_dispatch": tpd_b,
            "sharded_scaling_speedup": scaling,
            "sharded_replay_identical": identical,
            "sharded_hi_p99_ttft_s": hi_p99,
            "sharded_bulk_p99_ttft_s": bulk_p99,
            **{f"shard{i}_tok_s": agg_m[f"shard{i}_tok_s"]
               for i in range(ndev)},
        })
    return summary


if __name__ == "__main__":
    run()
