"""Paper Fig. 10 — KV-cache footprint ("device count") vs sequence length.

Reports per-layer KV bytes as input (a) and output (b) lengths grow, for
dense vs static-pruned vs static+dynamic UniCAIM (the mirror adds a small
overhead, mirroring the paper's 15× → 14.7× note)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import baselines
from repro.core.pruning import memory_footprint_bytes

HK, D = 8, 128


def run():
    budget = 576
    policies = {
        "dense": baselines.dense(10 ** 9),
        "static": baselines.h2o(heavy=budget - 64, reserve=64),
        "unicaim": baselines.unicaim(heavy=budget - 64, reserve=64,
                                     select_k=64, score_bits=3),
    }
    # (a) input sweep, 64 generated
    for n_in in (512, 1024, 2048, 4096, 8192, 16384, 32768):
        row = {}
        for name, p in policies.items():
            row[name] = memory_footprint_bytes(n_in + 64, HK, D, p)
        emit(f"footprint_in{n_in}", 0.0,
             f"dense_B={row['dense']};static_B={row['static']};"
             f"unicaim_B={row['unicaim']};"
             f"reduction={row['dense'] / row['unicaim']:.1f}x")
    # (b) output sweep, 2048 input
    for n_out in (64, 256, 1024, 4096, 16384):
        row = {name: memory_footprint_bytes(2048 + n_out, HK, D, p)
               for name, p in policies.items()}
        emit(f"footprint_out{n_out}", 0.0,
             f"dense_B={row['dense']};unicaim_B={row['unicaim']};"
             f"reduction={row['dense'] / row['unicaim']:.1f}x")


if __name__ == "__main__":
    run()
