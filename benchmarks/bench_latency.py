"""Paper Fig. 12 — decode latency. Three views:
  * measured CPU wall-time per decode attention step (dense vs UniCAIM
    composed vs the fused single-pass engine) at growing context — the
    paper's 'delay' with real code;
  * scan-amortized step time: 32 decode steps in one lax.scan dispatch,
    the serving path's per-token cost without Python dispatch overhead;
  * derived v5e roofline latency (memory term dominates decode).
The paper's ADC-count serialization has no TPU analog (DESIGN.md §7)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core import baselines
from repro.core.attention import decode_attention
from repro.core.cache import init_cache
from repro.launch.roofline import HBM_BW

B, HK, HQ, D = 2, 4, 8, 64
SCAN_STEPS = 32


def _step_fn(prune):
    return jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))


def _scan_fn(prune):
    def run(cache, q, k, v):
        def body(c, _):
            c, o = decode_attention(c, q, k, v, prune)
            return c, o
        return jax.lax.scan(body, cache, None, length=SCAN_STEPS)
    return jax.jit(run)


def run():
    ctxs = (512,) if common.SMOKE else (512, 1024, 2048, 4096)
    summary = {}
    for ctx in ctxs:
        budget = 576
        dense = baselines.dense(ctx)
        uni = baselines.unicaim(heavy=budget - 64, reserve=64, select_k=64,
                                score_bits=3, sink_tokens=2,
                                recent_window=8)
        fused = dataclasses.replace(uni, fused=True)
        rows = {}
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, HQ, D))
        kn = jax.random.normal(ks[1], (B, HK, D))
        vn = jax.random.normal(ks[2], (B, HK, D))
        for name, prune, slots in (("dense", dense, ctx),
                                   ("unicaim", uni, uni.slots),
                                   ("fused", fused, fused.slots)):
            cache = init_cache(B, HK, D, slots, prune, jnp.float32)
            fn = _step_fn(prune)
            c = cache
            for _ in range(min(slots + 8, 600) // 8):
                c, _ = fn(c, q, kn, vn)   # fill
            us = time_fn(lambda: fn(c, q, kn, vn))
            # scan-amortized per-step time (single dispatch for 32 steps)
            scan = _scan_fn(prune)
            us_scan = time_fn(lambda: scan(c, q, kn, vn)) / SCAN_STEPS
            # v5e derived latency: bytes moved / HBM bandwidth
            if name == "dense":
                bytes_moved = 2 * ctx * HK * D * 2
            else:
                from repro.core.quant import mirror_bytes_per_token
                bytes_moved = (min(ctx, uni.slots) * HK
                               * mirror_bytes_per_token(D, 3)
                               + 2 * uni.select_k * HK * D * 2)
            rows[name] = (us, us_scan, bytes_moved / HBM_BW * 1e6)
            emit(f"latency_{name}_ctx{ctx}", us,
                 f"scan_us={us_scan:.2f};v5e_us={rows[name][2]:.2f}")
        emit(f"latency_speedup_ctx{ctx}", 0.0,
             f"measured={rows['dense'][0] / rows['unicaim'][0]:.2f}x;"
             f"v5e_derived={rows['dense'][2] / rows['unicaim'][2]:.2f}x")
        emit(f"latency_fused_speedup_ctx{ctx}", 0.0,
             f"fused_vs_composed={rows['unicaim'][0] / rows['fused'][0]:.2f}x;"
             f"scan={rows['unicaim'][1] / rows['fused'][1]:.2f}x;"
             f"scan_vs_perstep={rows['fused'][0] / rows['fused'][1]:.2f}x")
        summary.update({
            f"dense_us_ctx{ctx}": rows["dense"][0],
            f"unicaim_us_ctx{ctx}": rows["unicaim"][0],
            f"fused_us_ctx{ctx}": rows["fused"][0],
            f"unicaim_scan_us_ctx{ctx}": rows["unicaim"][1],
            f"fused_scan_us_ctx{ctx}": rows["fused"][1],
            f"speedup_vs_dense_ctx{ctx}":
                rows["dense"][0] / rows["unicaim"][0],
            f"fused_speedup_ctx{ctx}":
                rows["unicaim"][0] / rows["fused"][0],
        })
    # machine-readable trajectory (written to BENCH_latency.json by
    # `benchmarks/run.py --smoke`; CI compares against the committed copy)
    return summary


if __name__ == "__main__":
    run()
