"""Paper Fig. 12 — decode latency. Four views:
  * measured CPU wall-time per decode attention step (dense vs UniCAIM
    composed vs the fused single-pass engine) at growing context — the
    paper's 'delay' with real code;
  * scan-amortized step time: 32 decode steps in one lax.scan dispatch,
    the serving path's per-token cost without Python dispatch overhead;
  * fill sweep: windowed decode at slots=4096 with fill ∈ {128, 512,
    2048, 4096} — step latency must GROW with the live context instead
    of sitting flat at the slots ceiling (the tentpole claim);
  * derived v5e roofline latency (memory term dominates decode).
The paper's ADC-count serialization has no TPU analog (DESIGN.md §7)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core import baselines, quant
from repro.core.attention import (decode_attention,
                                  decode_attention_stacked,
                                  fused_auto_decision,
                                  windowed_decode_attention)
from repro.core.cache import decode_window, init_cache
from repro.launch.roofline import HBM_BW
from repro.launch.serve import donation_mode

B, HK, HQ, D = 2, 4, 8, 64
SCAN_STEPS = 32
SWEEP_SLOTS = 4096
SWEEP_FILLS = (128, 512, 2048, 4096)


def _step_fn(prune):
    return jax.jit(lambda c, q, k, v: decode_attention(c, q, k, v, prune))


def _scan_fn(prune):
    def run(cache, q, k, v):
        def body(c, _):
            c, o = decode_attention(c, q, k, v, prune)
            return c, o
        return jax.lax.scan(body, cache, None, length=SCAN_STEPS)
    return jax.jit(run)


def _filled_cache(fill: int, slots: int, prune, key=0):
    """Cache with `fill` live slots in the [0, fill) prefix — exactly the
    layout prefill + append-only decode produce (bench shortcut: the
    contents are random, the metadata is faithful)."""
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    c = init_cache(B, HK, D, slots, prune, jnp.float32)
    k = jax.random.normal(ks[0], (B, HK, slots, D))
    v = jax.random.normal(ks[1], (B, HK, slots, D))
    live = jnp.broadcast_to(jnp.arange(slots)[None, None, :] < fill,
                            (B, HK, slots))
    kq, kscale = quant.quantize(k, prune.score_bits)
    pos = jnp.broadcast_to(jnp.arange(slots)[None, None, :], (B, HK, slots))
    return c._replace(
        k=jnp.where(live[..., None], k, 0).astype(c.k.dtype),
        v=jnp.where(live[..., None], v, 0).astype(c.v.dtype),
        kq=jnp.where(live[..., None], kq, 0),
        kscale=jnp.where(live, kscale, 0.0),
        acc=jax.random.uniform(ks[2], (B, HK, slots)) * live,
        valid=live, pos=jnp.where(live, pos, -1),
        fill=jnp.full((B,), fill, jnp.int32),
        step=jnp.full((B,), fill, jnp.int32))


def _fill_sweep(summary):
    """Windowed decode at slots=4096: step cost must track fill, not S."""
    prune = baselines.unicaim(heavy=SWEEP_SLOTS - 64, reserve=64,
                              select_k=64, score_bits=3, sink_tokens=2,
                              recent_window=8)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, HQ, D))
    kn = jax.random.normal(ks[1], (B, HK, D))
    vn = jax.random.normal(ks[2], (B, HK, D))
    rows = {}
    for fill in SWEEP_FILLS:
        cache = _filled_cache(fill, SWEEP_SLOTS, prune, key=fill)
        w = decode_window(fill, 1, SWEEP_SLOTS, prune)
        fn = jax.jit(lambda c, q, k, v, w=w: windowed_decode_attention(
            c, q, k, v, prune, w))
        us = time_fn(lambda: fn(cache, q, kn, vn))
        rows[fill] = us
        emit(f"latency_win_fill{fill}_slots{SWEEP_SLOTS}", us,
             f"window={w or SWEEP_SLOTS}")
        summary[f"unicaim_win_us_fill{fill}_slots{SWEEP_SLOTS}"] = us
        summary[f"unicaim_win_us_fill{fill}_slots{SWEEP_SLOTS}_p50"] = us.p50
    speedup = rows[SWEEP_FILLS[-1]] / rows[SWEEP_FILLS[0]]
    emit(f"latency_win_speedup_fill{SWEEP_FILLS[0]}_vs_{SWEEP_SLOTS}", 0.0,
         f"step_cost_ratio={speedup:.2f}x")
    summary["win_speedup_fill128_vs_4096"] = speedup
    _inplace_fill_sweep(summary, prune, q, kn, vn, rows)


def _inplace_fill_sweep(summary, prune, q, kn, vn, win_rows):
    """In-place stacked decode at slots=4096: the serving path's step.

    Same cache layouts as the functional sweep, but stepping through
    `decode_attention_stacked` on a 1-layer stacked cache carried by a
    SCAN_STEPS-long lax.scan — the exact shape ServeLoop's decode block
    compiles. The scan carry updates in place inside the compiled while
    loop (even on CPU, where jit-boundary donation is a no-op — see
    `donation_mode`), so the per-step cost drops the per-dispatch
    cache-copy floor the functional rows pay."""
    for fill in SWEEP_FILLS:
        kv = jax.tree.map(lambda a: a[None],
                          _filled_cache(fill, SWEEP_SLOTS, prune, key=fill))
        w = decode_window(fill, SCAN_STEPS, SWEEP_SLOTS, prune)

        def run(kv, q, k, v, w=w):
            def body(c, _):
                c, o = decode_attention_stacked(c, 0, q, k, v, prune, w,
                                                None)
                return c, o
            return jax.lax.scan(body, kv, None, length=SCAN_STEPS)

        fn = jax.jit(run)
        us = time_fn(lambda: fn(kv, q, kn, vn)) / SCAN_STEPS
        emit(f"latency_inplace_fill{fill}_slots{SWEEP_SLOTS}", us,
             f"window={w or SWEEP_SLOTS};scan_steps={SCAN_STEPS};"
             f"vs_functional={win_rows[fill] / us:.2f}x")
        summary[f"unicaim_inplace_us_fill{fill}_slots{SWEEP_SLOTS}"] = us
    speedup = (win_rows[SWEEP_FILLS[0]]
               / summary[f"unicaim_inplace_us_fill{SWEEP_FILLS[0]}"
                         f"_slots{SWEEP_SLOTS}"])
    emit(f"latency_inplace_speedup_fill{SWEEP_FILLS[0]}", 0.0,
         f"inplace_vs_functional={speedup:.2f}x")
    summary["inplace_speedup_fill128"] = speedup


def run():
    ctxs = (512,) if common.SMOKE else (512, 1024, 2048, 4096)
    summary = {}
    for ctx in ctxs:
        budget = 576
        dense = baselines.dense(ctx)
        uni = baselines.unicaim(heavy=budget - 64, reserve=64, select_k=64,
                                score_bits=3, sink_tokens=2,
                                recent_window=8)
        fused = dataclasses.replace(uni, fused=True)
        rows = {}
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, HQ, D))
        kn = jax.random.normal(ks[1], (B, HK, D))
        vn = jax.random.normal(ks[2], (B, HK, D))
        for name, prune, slots in (("dense", dense, ctx),
                                   ("unicaim", uni, uni.slots),
                                   ("fused", fused, fused.slots)):
            cache = init_cache(B, HK, D, slots, prune, jnp.float32)
            fn = _step_fn(prune)
            c = cache
            for _ in range(min(slots + 8, 600) // 8):
                c, _ = fn(c, q, kn, vn)   # fill
            us = time_fn(lambda: fn(c, q, kn, vn))
            # scan-amortized per-step time (single dispatch for 32 steps)
            scan = _scan_fn(prune)
            us_scan = time_fn(lambda: scan(c, q, kn, vn)) / SCAN_STEPS
            # v5e derived latency: bytes moved / HBM bandwidth
            if name == "dense":
                bytes_moved = 2 * ctx * HK * D * 2
            else:
                from repro.core.quant import mirror_bytes_per_token
                bytes_moved = (min(ctx, uni.slots) * HK
                               * mirror_bytes_per_token(D, 3)
                               + 2 * uni.select_k * HK * D * 2)
            rows[name] = (us, us_scan, bytes_moved / HBM_BW * 1e6)
            emit(f"latency_{name}_ctx{ctx}", us,
                 f"scan_us={us_scan:.2f};v5e_us={rows[name][2]:.2f}")
        emit(f"latency_speedup_ctx{ctx}", 0.0,
             f"measured={rows['dense'][0] / rows['unicaim'][0]:.2f}x;"
             f"v5e_derived={rows['dense'][2] / rows['unicaim'][2]:.2f}x")
        emit(f"latency_fused_speedup_ctx{ctx}", 0.0,
             f"fused_vs_composed={rows['unicaim'][0] / rows['fused'][0]:.2f}x;"
             f"scan={rows['unicaim'][1] / rows['fused'][1]:.2f}x;"
             f"scan_vs_perstep={rows['fused'][0] / rows['fused'][1]:.2f}x")
        summary.update({
            f"dense_us_ctx{ctx}": rows["dense"][0],
            f"unicaim_us_ctx{ctx}": rows["unicaim"][0],
            f"fused_us_ctx{ctx}": rows["fused"][0],
            f"unicaim_scan_us_ctx{ctx}": rows["unicaim"][1],
            f"fused_scan_us_ctx{ctx}": rows["fused"][1],
            f"speedup_vs_dense_ctx{ctx}":
                rows["dense"][0] / rows["unicaim"][0],
            f"fused_speedup_ctx{ctx}":
                rows["unicaim"][0] / rows["fused"][0],
        })
    # fused="auto" record: which engine auto picks on this backend and
    # why (the acceptance gate for the fused path: either the forced
    # measurement shows speedup >= 1.0, or auto selects composed with the
    # decision recorded here)
    decision = fused_auto_decision()
    summary["fused_auto_engine"] = decision["engine"]
    summary["fused_auto_reason"] = decision["reason"]
    summary["donation"] = donation_mode()
    emit("latency_fused_auto", 0.0,
         f"engine={decision['engine']};backend={decision['backend']}")
    _fill_sweep(summary)
    # machine-readable trajectory (written to BENCH_latency.json by
    # `benchmarks/run.py --smoke`; CI compares against the committed copy)
    return summary


if __name__ == "__main__":
    run()
