"""Prefill-stage one-shot static pruning pipeline (§III-A.1).

Runs the chunked causal attention over the prompt, harvests the accumulated
attention column sums, and fills the fixed-slot cache with the heavy tokens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.configs.base import PruneConfig
from repro.core.attention import chunked_causal_attention
from repro.core.cache import KVCache, prefill_fill


def prefill_and_prune(cache: KVCache, q: jax.Array, k: jax.Array,
                      v: jax.Array, prune: PruneConfig,
                      chunk: int = 512,
                      length: Optional[jax.Array] = None,
                      ) -> Tuple[KVCache, jax.Array]:
    """q: [B,Hq,N,d]; k/v: [B,Hk,N,d] → (pruned cache, prefill out).

    `length` ([B] int32, optional): true per-lane prompt lengths when the
    inputs are right-padded to a shape-stable bucket N — pad rows/columns
    neither attend, accumulate, nor enter the static top-k.
    """
    out, acc = chunked_causal_attention(
        q, k, v, chunk=chunk, obs_window=prune.prefill_obs_window,
        length=length)
    cache = prefill_fill(cache, k, v, acc, prune, length=length)
    return cache, out


def memory_footprint_bytes(n_tokens: int, n_kv_heads: int, head_dim: int,
                           prune: PruneConfig, kv_bytes: int = 2) -> int:
    """Per-layer KV bytes under a policy (paper Fig. 10 'device count').

    dense: grows with n_tokens; pruned policies: fixed S=H+M slots (+ the
    quantized mirror for unicaim).
    """
    if prune.policy == "dense":
        tokens = n_tokens
        mirror = 0
    else:
        tokens = min(n_tokens, prune.slots)
        mirror = 0
        if prune.policy == "unicaim":
            from repro.core.quant import mirror_bytes_per_token
            mirror = tokens * n_kv_heads * mirror_bytes_per_token(
                head_dim, prune.score_bits)
    kv = 2 * tokens * n_kv_heads * head_dim * kv_bytes
    acc_table = 0 if prune.policy == "dense" else tokens * n_kv_heads * 4
    return kv + mirror + acc_table
