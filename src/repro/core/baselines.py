"""Baseline KV-cache policies the paper compares against (Table I, Fig. 13).

All baselines run on the same fixed-slot cache machinery — they differ only
in scoring precision, selection, and eviction rule:

  dense        — no pruning; cache sized to the full sequence.
  streaming    — StreamingLLM [19]: attention sinks + sliding window
                 (position-based ring eviction, no scores).
  h2o          — H2O [7]: exact-score accumulation, static argmin eviction,
                 attends to ALL cached tokens (no dynamic top-k).
  snapkv       — SnapKV [8]-style: prefill selection from an observation
                 window; decode behaves like h2o.
  unicaim      — the paper: quantized approx scoring + top-k + static evict.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import PruneConfig


def dense(max_seq: int) -> PruneConfig:
    return PruneConfig(policy="dense", heavy_budget=max_seq, reserve=0,
                       sink_tokens=0, recent_window=1, select_k=1)


def streaming(budget: int, sinks: int = 4) -> PruneConfig:
    return PruneConfig(policy="streaming", heavy_budget=budget, reserve=0,
                       sink_tokens=sinks, recent_window=1, select_k=1)


def h2o(heavy: int, reserve: int, recent: int = 32) -> PruneConfig:
    return PruneConfig(policy="h2o", heavy_budget=heavy, reserve=reserve,
                       recent_window=recent, select_k=1, accumulate="exact")


def snapkv(heavy: int, reserve: int, obs_window: int = 32,
           recent: int = 32) -> PruneConfig:
    return PruneConfig(policy="h2o", heavy_budget=heavy, reserve=reserve,
                       recent_window=recent, select_k=1, accumulate="exact",
                       prefill_obs_window=obs_window)


def unicaim(heavy: int, reserve: int, select_k: int, score_bits: int = 3,
            query_bits: int = 4, **kw) -> PruneConfig:
    return PruneConfig(policy="unicaim", heavy_budget=heavy, reserve=reserve,
                       select_k=select_k, score_bits=score_bits,
                       query_bits=query_bits, **kw)


def with_budget(cfg: PruneConfig, heavy: int, reserve: int) -> PruneConfig:
    return dataclasses.replace(cfg, heavy_budget=heavy, reserve=reserve)
