"""UniCAIMCache — the fixed-slot KV cache with in-place overwrite (§III-B).

The FeFET array holds S = H + M rows per kv-head; eviction never compacts,
it re-programs one row (single WL write cycle). The TPU equivalent is a
statically-shaped slot array written by scatter — jit/scan friendly, no
re-layout, and shardable as [batch→data, kv_heads→model, slots→·].

One instance per layer; models stack instances along a leading layer axis
and scan over it.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig
from repro.core import quant


class KVCache(NamedTuple):
    k: jax.Array                    # [B, Hk, S, dh] compute dtype or int8
    v: Optional[jax.Array]          # [B, Hk, S, dv] (None for MLA latent)
    kq: Optional[jax.Array]         # [B, Hk, S, dh] int8 mirror (CAM cells);
                                    # None in int8 mode (k IS the mirror)
    kscale: Optional[jax.Array]     # [B, Hk, S] f32 (mirror or int8-K scale)
    vscale: Optional[jax.Array]     # [B, Hk, S] f32 (int8 mode only)
    acc: jax.Array                  # [B, Hk, S] f32 accumulated scores
    valid: jax.Array                # [B, Hk, S] bool
    pos: jax.Array                  # [B, Hk, S] int32 (absolute; -1 empty)
    fill: jax.Array                 # [B] int32 slots filled
    step: jax.Array                 # [B] int32 tokens seen (next abs pos)

    @property
    def slots(self) -> int:
        return self.k.shape[-2]

    @property
    def quantized_kv(self) -> bool:
        return self.k.dtype == jnp.int8

    def k_values(self) -> jax.Array:
        """K rows in compute precision (dequantized in int8 mode)."""
        if self.quantized_kv:
            return quant.dequantize(self.k, self.kscale)
        return self.k

    def v_values(self) -> Optional[jax.Array]:
        if self.v is not None and self.quantized_kv:
            return quant.dequantize(self.v, self.vscale)
        return self.v


def init_cache(batch: int, n_kv_heads: int, head_dim: int, slots: int,
               prune: PruneConfig, dtype=jnp.bfloat16,
               v_dim: Optional[int] = None, latent: bool = False) -> KVCache:
    """Empty cache. `latent=True` → MLA mode (no V, mirror over latent)."""
    if v_dim is None:
        v_dim = head_dim
    shape = (batch, n_kv_heads, slots, head_dim)
    int8_kv = prune.kv_dtype == "int8"
    if int8_kv:
        assert prune.policy == "unicaim", "int8 KV is a unicaim-mode knob"
        dtype = jnp.int8
    # int8 K doubles as the CAM mirror → no separate copy
    needs_mirror = prune.policy == "unicaim" and not int8_kv
    needs_scale = needs_mirror or int8_kv
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=None if latent else jnp.zeros((batch, n_kv_heads, slots, v_dim),
                                        dtype),
        kq=jnp.zeros(shape, jnp.int8) if needs_mirror else None,
        kscale=jnp.zeros(shape[:3], jnp.float32) if needs_scale else None,
        vscale=(jnp.zeros(shape[:3], jnp.float32)
                if int8_kv and not latent else None),
        acc=jnp.zeros(shape[:3], jnp.float32),
        valid=jnp.zeros(shape[:3], jnp.bool_),
        pos=jnp.full(shape[:3], -1, jnp.int32),
        fill=jnp.zeros((batch,), jnp.int32),
        step=jnp.zeros((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Per-lane (batch-slot) surgery — continuous-batching support.
#
# A serving engine keeps one live batched cache and recycles individual
# batch lanes as requests finish: slice a lane out, reset it, or splice a
# freshly prefilled batch-1 cache into it without disturbing the others.
# `batch_axis=0` operates on a single-layer cache; `batch_axis=1` on the
# layer-stacked caches models carry in their DecodeState ([L, B, ...]).
# All fields move together — including the quantized mirrors (kq/kscale/
# vscale) and the accumulated scores — so eviction state is per-lane exact.
# ---------------------------------------------------------------------------


def lane_slice(cache: KVCache, lane, batch_axis: int = 0) -> KVCache:
    """Extract one lane as a batch-1 cache (jit-safe; `lane` may be traced)."""
    def sl(a):
        if a is None:
            return None
        return jax.lax.dynamic_slice_in_dim(a, lane, 1, axis=batch_axis)
    return KVCache(*(sl(f) for f in cache))


def lane_insert(cache: KVCache, lane, fresh: KVCache,
                batch_axis: int = 0) -> KVCache:
    """Splice a batch-1 `fresh` cache into lane `lane` of a live cache."""
    def ins(a, f):
        if a is None:
            return None
        return jax.lax.dynamic_update_slice_in_dim(
            a, f.astype(a.dtype), lane, axis=batch_axis)
    return KVCache(*(ins(a, f) for a, f in zip(cache, fresh)))


def lanes_insert(cache: KVCache, src, fresh: KVCache,
                 batch_axis: int = 0) -> KVCache:
    """Multi-lane splice: scatter rows of a batch-G `fresh` cache into a
    live cache in ONE shot (grouped admission).

    `src` is an int32 [B_live] map from live lane to `fresh` row: lane b
    takes `fresh` row `src[b]` when `src[b] >= 0` and keeps its current
    contents at -1. Formulated as gather + select (not a scatter) so the
    compiled program is shape-stable in the group size: how many lanes a
    round actually fills is data, not shape. Writes exact copies of every
    field — bit-identical to G sequential `lane_insert` calls."""
    src = jnp.asarray(src, jnp.int32)
    keep = src < 0
    idx = jnp.maximum(src, 0)

    def ins(a, f):
        if a is None:
            return None
        g = jnp.take(f.astype(a.dtype), idx, axis=batch_axis)
        m = keep.reshape((1,) * batch_axis + (-1,)
                         + (1,) * (a.ndim - batch_axis - 1))
        return jnp.where(m, a, g)

    return KVCache(*(ins(a, f) for a, f in zip(cache, fresh)))


def lane_reset(cache: KVCache, lane, batch_axis: int = 0) -> KVCache:
    """Return `cache` with one lane emptied (as `init_cache` would make it)."""
    def blank(a, fill_value=0):
        if a is None:
            return None
        shape = list(a.shape)
        shape[batch_axis] = 1
        return jnp.full(shape, fill_value, a.dtype)
    empty = KVCache(
        k=blank(cache.k), v=blank(cache.v), kq=blank(cache.kq),
        kscale=blank(cache.kscale), vscale=blank(cache.vscale),
        acc=blank(cache.acc), valid=blank(cache.valid),
        pos=blank(cache.pos, -1), fill=blank(cache.fill),
        step=blank(cache.step))
    return lane_insert(cache, lane, empty, batch_axis=batch_axis)


# ---------------------------------------------------------------------------
# Slot windows — fill-aware decode cost.
#
# Every write path is prefix-packed: `prefill_fill` scatters the kept tokens
# into slots [0, keep) and `write_token` appends at slot `fill` until the
# cache is full, after which eviction re-programs a slot that is already
# < fill. A lane with fill=f therefore has ALL its live slots inside [0, f),
# and a decode step only ever reads/writes slots [0, max_fill + 1). Slicing
# every slot-axis field to a prefix window W >= max_fill + steps gives a
# shape-stable view whose decode math is bit-identical to the full-width
# cache (slots >= fill are invalid: their scores are NEG_INF-masked, their
# probabilities are exactly zero, and their accumulated scores are exactly
# zero, so dropping them removes only exact-zero/masked work). The serving
# engine quantizes W to powers of two so the jit cache gains at most
# log2(slots) windowed programs per decode-block shape.
#
# Ring-wrap handling: the streaming policy's ring eviction (and unicaim/h2o
# argmin eviction) only engages once a lane is FULL — `_choose_slot` appends
# while fill < slots — and a full lane forces W == slots (`decode_window`
# returns None), so a windowed program never sees a wrapped write.
# ---------------------------------------------------------------------------


def slot_window(cache: KVCache, w: int) -> KVCache:
    """View of the first `w` slots of every slot-axis field.

    Works on single-layer ([B, Hk, S, ·]) and layer-stacked ([L, B, Hk,
    S, ·]) caches alike: the slot axis is located from the trailing end
    (k/v/kq at ndim-2, the per-slot scalars at ndim-1); `fill`/`step`
    carry no slot axis and pass through."""
    def cut(a, ax_from_end):
        if a is None:
            return None
        idx = [slice(None)] * a.ndim
        idx[a.ndim - ax_from_end] = slice(0, w)
        return a[tuple(idx)]
    return KVCache(
        k=cut(cache.k, 2), v=cut(cache.v, 2), kq=cut(cache.kq, 2),
        kscale=cut(cache.kscale, 1), vscale=cut(cache.vscale, 1),
        acc=cut(cache.acc, 1), valid=cut(cache.valid, 1),
        pos=cut(cache.pos, 1), fill=cache.fill, step=cache.step)


def slot_window_merge(full: KVCache, win: KVCache) -> KVCache:
    """Write a windowed cache back over the first `w` slots of `full`.

    Together with `slot_window` this brackets a decode step: slots beyond
    the window were untouched by construction (invalid, zero-acc), so the
    merged cache is bit-identical to running the step at full width."""
    def put(a, wa, ax_from_end):
        if a is None:
            return None
        ax = a.ndim - ax_from_end
        if wa.shape[ax] == a.shape[ax]:
            return wa
        return jax.lax.dynamic_update_slice_in_dim(a, wa, 0, axis=ax)
    return KVCache(
        k=put(full.k, win.k, 2), v=put(full.v, win.v, 2),
        kq=put(full.kq, win.kq, 2),
        kscale=put(full.kscale, win.kscale, 1),
        vscale=put(full.vscale, win.vscale, 1),
        acc=put(full.acc, win.acc, 1), valid=put(full.valid, win.valid, 1),
        pos=put(full.pos, win.pos, 1), fill=win.fill, step=win.step)


def decode_window(max_fill: int, steps: int, slots: int,
                  prune: PruneConfig, grid: Union[str, int] = "pow2",
                  ) -> Optional[int]:
    """Slot window covering `steps` decode steps from `max_fill`, or None
    when only the full width is valid.

    The window must hold every live slot plus the `steps` about-to-append
    tokens, and stay wide enough for the selection machinery: at least
    `select_k` slots so top-k never exceeds the axis, and a multiple of
    `select_blocks` so the hierarchical race partitions evenly (odd block
    counts that don't divide the window fall back to full width). Returns
    None — run unwindowed — once the window reaches the allocated slot
    count (including every full lane, where eviction and ring wrap-around
    engage).

    `grid` picks the quantization of the window width, which bounds how
    many distinct programs the jit cache can accumulate per decode-block
    shape: ``"pow2"`` (default) rounds up to a power of two (≤ log2(slots)
    programs, the coarsest grid — up to 2x oversized between 2^n and
    2^(n+1)); an int `c` rounds up to the next multiple of `c` (≤ slots/c
    programs — an additive chunk grid for tighter fits, e.g. c =
    cfg.attn_chunk keeps the window within one chunk of the live
    context). Both grids honour the same select_k/select_blocks floor, so
    either window is bit-identical to the full-width step."""
    need = max(int(max_fill) + max(steps, 1), prune.select_k, 1)
    if grid == "pow2":
        w = 1 << (need - 1).bit_length()
    else:
        c = max(1, int(grid))
        w = -(-need // c) * c
    nb = max(1, prune.select_blocks)
    if w % nb or prune.select_k % nb:
        return None
    return None if w >= slots else w


def layer_window(cache: KVCache, li, w: int) -> KVCache:
    """Windowed READ view of one layer of a stacked ([L, B, Hk, S, ·])
    cache: `dynamic_slice` out layer `li` (a traced scalar — the layer
    scan's position) and the first `w` slots of every slot-axis field.

    This is the read half of the in-place decode split: slicing is a pure
    read, so taking the view does NOT break XLA input–output aliasing of
    the full-width buffers the way `slot_window` + `slot_window_merge`
    round-trips do. Writes go back through `write_token_stacked` /
    targeted `dynamic_update_slice` at layer `li` instead."""
    li = jnp.asarray(li, jnp.int32)

    def cut(a, ax_from_end):
        if a is None:
            return None
        lw = jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False)
        ax = lw.ndim - ax_from_end
        return jax.lax.slice_in_dim(lw, 0, w, axis=ax)

    def row(a):
        return (None if a is None
                else jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False))

    return KVCache(
        k=cut(cache.k, 2), v=cut(cache.v, 2), kq=cut(cache.kq, 2),
        kscale=cut(cache.kscale, 1), vscale=cut(cache.vscale, 1),
        acc=cut(cache.acc, 1), valid=cut(cache.valid, 1),
        pos=cut(cache.pos, 1), fill=row(cache.fill), step=row(cache.step))


# ---------------------------------------------------------------------------
# Prefix snapshots — prefix-sharing admission support.
#
# `prefill_fill` scatters the static top-k winners into the slot prefix, so
# a finalized cache generally holds a position-scattered SUBSET of the
# prompt — useless as a donor for a longer prompt that shares the prefix.
# But when nothing was pruned (prompt no longer than the keep budget) the
# layout is the identity: slot i holds token i in order, `acc` is the raw
# accumulated column sums, and rows [0, length) ARE the pre-pruning
# workspace a chunked prefill would stream — i.e. a valid resume donor
# (`Model.resume_prefill_chunk_state`). These host-side helpers detect that
# alignment and extract the rows; the serving engine's prefix cache uses
# them to turn completed whole-bucket prefills into radix-trie donors
# (`launch/prefix_cache.py`). int8 caches are never slot-aligned donors:
# quantization at finalize is lossy, so the raw rows are unrecoverable —
# their donors come from pre-quantization workspace snapshots instead.
# ---------------------------------------------------------------------------


def prefix_slot_aligned(kv: KVCache, length: int) -> bool:
    """True when rows [0, length) of a finalized cache are the raw prompt
    K/V in original token order — static pruning kept everything, so the
    slot layout is the identity over the prefix.

    Host-side check over a batch-1 cache (single-layer [1, Hk, S, ·] or
    layer-stacked [L, 1, Hk, S, ·]): every lane/layer must have
    fill == step == length, positions 0..length-1 in order, a fully valid
    prefix, and full-precision storage (int8 rows are quantized in place
    and unrecoverable)."""
    if kv.quantized_kv or kv.v is None:
        return False
    if length <= 0 or length > kv.slots:
        return False
    fill = np.asarray(kv.fill)
    step = np.asarray(kv.step)
    if not ((fill == length).all() and (step == length).all()):
        return False
    pos = np.asarray(kv.pos)[..., :length]
    if not (pos == np.arange(length, dtype=pos.dtype)).all():
        return False
    return bool(np.asarray(kv.valid)[..., :length].all())


def cache_prefix_rows(kv: KVCache, length: int
                      ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Extract (k, v, acc) rows [0, length) of a slot-aligned finalized
    batch-1 layer-stacked cache as host arrays ([L, Hk, length, ·] /
    [L, Hk, length]), or None when the prefix is not slot-aligned (static
    pruning rewrote it, or the cache is quantized/latent).

    The returned rows equal the pre-pruning chunked-prefill workspace for
    the same tokens — `prefill_fill` stores the RAW accumulated scores and
    gathers with an identity index map when nothing is evicted — so they
    can seed `Model.resume_prefill_chunk_state`. K/V rows match bit-for-
    bit unconditionally; the f32 `acc` sums match bit-for-bit when the
    donor prefill's query-chunk grid equals the resume chunk size (the
    engine pins `chunk_prefill == cfg.attn_chunk` for that; any other
    pairing agrees to float-association noise).

    Donors are prefills AND preempted lanes: a victim captured before any
    decode step advanced it still satisfies the slot-alignment gate
    (fill == step == prompt length, identity positions), so the serving
    engine feeds its rows to the prefix trie on eviction
    (`ServeLoop._cache_insert_preempted`). The gate runs on the cheap
    host-side light fields first, so decode-advanced captures are
    refused before any k/v/acc device→host copy."""
    if not prefix_slot_aligned(kv, length):
        return None
    k = np.asarray(kv.k)[:, 0, :, :length]
    v = np.asarray(kv.v)[:, 0, :, :length]
    acc = np.asarray(kv.acc)[:, 0, :, :length]
    return k, v, acc


def protected_mask(cache: KVCache, prune: PruneConfig) -> jax.Array:
    """[B, Hk, S] — slots that must never be evicted (sinks + recent)."""
    is_sink = (cache.pos >= 0) & (cache.pos < prune.sink_tokens)
    recent_floor = cache.step[:, None, None] - prune.recent_window
    is_recent = cache.pos >= recent_floor
    return cache.valid & (is_sink | is_recent)


def evictable_mask(cache: KVCache, prune: PruneConfig) -> jax.Array:
    return cache.valid & ~protected_mask(cache, prune)


def _choose_slot(cache: KVCache, prune: PruneConfig) -> jax.Array:
    """Per-(B, Hk) write slot: append while space, else policy eviction."""
    b, hk, s = cache.acc.shape
    append = cache.fill[:, None]                                   # [B,1]
    if prune.policy == "streaming":
        # ring over the non-sink region (StreamingLLM)
        window = s - prune.sink_tokens
        ring = prune.sink_tokens + (cache.step[:, None] - prune.sink_tokens) % window
        slot = jnp.where(cache.fill[:, None] < s, append, ring)
        return jnp.broadcast_to(slot, (b, hk)).astype(jnp.int32)
    # unicaim / h2o: argmin accumulated score among evictable slots
    score = jnp.where(evictable_mask(cache, prune), cache.acc, jnp.inf)
    evict = jnp.argmin(score, axis=-1)                             # [B,Hk]
    full = cache.fill[:, None] >= s
    return jnp.where(full, evict, jnp.broadcast_to(append, (b, hk))).astype(jnp.int32)


def _token_writes(cache: KVCache, k_new: jax.Array,
                  v_new: Optional[jax.Array], prune: PruneConfig,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Slot choice + per-field row values for a one-token insert.

    Returns (slot [B, Hk], {field: [B, Hk, ·] value to store at slot}).
    Shared by the functional `write_token` (scatter into THIS cache) and
    the in-place stacked path (`write_token_stacked` — scatter into the
    full-width layer-stacked buffers while only the window was read)."""
    b, hk, _ = cache.acc.shape
    slot = _choose_slot(cache, prune)                              # [B,Hk]
    vals: Dict[str, jax.Array] = {}
    if cache.quantized_kv:
        vals["k"], vals["kscale"] = quant.quantize(k_new, 8)
        if cache.v is not None:
            vals["v"], vals["vscale"] = quant.quantize(v_new, 8)
    else:
        vals["k"] = k_new.astype(cache.k.dtype)
        if cache.v is not None:
            vals["v"] = v_new.astype(cache.v.dtype)
        if cache.kq is not None:
            vals["kq"], vals["kscale"] = quant.quantize(k_new,
                                                        prune.score_bits)
    if prune.init_new_score == "mean":
        denom = jnp.maximum(jnp.sum(cache.valid, axis=-1), 1)
        vals["acc"] = (jnp.sum(jnp.where(cache.valid, cache.acc, 0.0),
                               axis=-1) / denom)
    else:
        vals["acc"] = jnp.zeros((b, hk), jnp.float32)
    vals["valid"] = jnp.ones((b, hk), jnp.bool_)
    vals["pos"] = jnp.broadcast_to(cache.step[:, None], (b, hk)
                                   ).astype(jnp.int32)
    return slot, vals


def write_token(cache: KVCache, k_new: jax.Array,
                v_new: Optional[jax.Array], prune: PruneConfig) -> KVCache:
    """Insert one token (decode step): static eviction + in-place overwrite.

    k_new: [B, Hk, dh]; v_new: [B, Hk, dv] or None (latent mode).
    """
    b, hk, s = cache.acc.shape
    slot, vals = _token_writes(cache, k_new, v_new, prune)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(hk)[None, :]
    upd = {f: getattr(cache, f).at[bi, hi, slot].set(v)
           for f, v in vals.items()}
    return cache._replace(
        **upd, fill=jnp.minimum(cache.fill + 1, s), step=cache.step + 1)


def write_token_stacked(cache: KVCache, li, slot: jax.Array,
                        vals: Dict[str, jax.Array],
                        active: Optional[jax.Array]) -> KVCache:
    """Storage half of the in-place decode split: scatter one token's row
    values (from `_token_writes` over a windowed READ view) straight into
    the FULL-WIDTH layer-stacked buffers at layer `li`.

    Each field writes O(B·Hk·dh) bytes — never the O(S) round-trip of
    `slot_window_merge` — so XLA keeps the stacked buffers aliased
    input-to-output through the layer scan and the jitted decode block.
    `active` ([B] bool, optional) gates lanes at the SOURCE: an inactive
    lane's slot index is pushed out of bounds and the scatter drops it
    (`mode="drop"`), which replaces the full-width `jnp.where` merge of
    `state_lane_select` for every cache field. Bit-identical to
    `write_token` + lane-select for active lanes: active lanes always
    append inside the window (`decode_window` covers fill + steps), and
    the clamp `min(fill+1, S)` matches the windowed `min(fill+1, W)`
    there."""
    s = cache.slots
    b, hk = slot.shape
    li = jnp.asarray(li, jnp.int32)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(hk)[None, :]
    if active is not None:
        slot = jnp.where(active[:, None], slot, s)     # OOB → dropped
    upd = {f: getattr(cache, f).at[li, bi, hi, slot].set(
               v, mode="drop", unique_indices=True)
           for f, v in vals.items()}
    fill_l = jax.lax.dynamic_index_in_dim(cache.fill, li, 0, keepdims=False)
    step_l = jax.lax.dynamic_index_in_dim(cache.step, li, 0, keepdims=False)
    new_fill = jnp.minimum(fill_l + 1, s)
    new_step = step_l + 1
    if active is not None:
        new_fill = jnp.where(active, new_fill, fill_l)
        new_step = jnp.where(active, new_step, step_l)
    return cache._replace(
        **upd,
        fill=jax.lax.dynamic_update_index_in_dim(cache.fill, new_fill, li, 0),
        step=jax.lax.dynamic_update_index_in_dim(cache.step, new_step, li, 0))


def prefill_fill(cache: KVCache, k_full: jax.Array,
                 v_full: Optional[jax.Array], acc_scores: jax.Array,
                 prune: PruneConfig,
                 length: Optional[jax.Array] = None) -> KVCache:
    """One-shot static pruning after prefill (§III-A.1).

    k_full: [B, Hk, N, dh] prompt keys; acc_scores: [B, Hk, N] accumulated
    attention column-sums from the prefill pass. Keeps the `heavy_budget`
    heaviest tokens per kv-head (sinks + recent always kept), scattered into
    slots [0..H).  N >= heavy_budget is required (configs guarantee it);
    if the policy is dense/streaming the first min(N, S) tokens are kept.

    `length` ([B] int32, optional) is the true per-lane prompt length when
    the inputs are right-padded to a shape-stable bucket N: the sink/recent
    bias anchors on the true length, padded tokens rank -inf so they can
    never win the static top-k, any pad that top-k is nevertheless forced
    to hand back (prompt shorter than the keep budget) is stored as an
    all-zero INVALID slot — exactly what an exact-length prefill followed
    by `jnp.pad` produces — and `pos`/`fill`/`step` reflect the real
    length, not the bucket.
    """
    b, hk, n, dh = k_full.shape
    s = cache.slots
    keep = min(prune.heavy_budget, n, s)
    bucketed = length is not None
    if length is None:
        length = jnp.full((b,), n, jnp.int32)
    length = jnp.minimum(length.astype(jnp.int32), n)

    pos_ids = jnp.arange(n)
    is_pad = pos_ids[None, :] >= length[:, None]                   # [B,N]
    if prune.policy in ("unicaim", "h2o"):
        sink = pos_ids[None, :] < prune.sink_tokens
        recent = pos_ids[None, :] >= (length[:, None] - prune.recent_window)
        bias = (jnp.where(sink, jnp.inf, 0.0)
                + jnp.where(recent, jnp.inf, 0.0))                 # [B,N]
        ranked = acc_scores + bias[:, None, :]
    else:
        # dense/streaming keep the most recent tokens (+ sinks for streaming)
        ranked = pos_ids.astype(jnp.float32)[None, None, :] * jnp.ones((b, hk, 1))
        if prune.policy == "streaming":
            ranked = ranked + jnp.where(pos_ids < prune.sink_tokens,
                                        jnp.inf, 0.0)[None, None, :]
    # padded tokens never win (where, not addition: bias may already be inf)
    ranked = jnp.where(is_pad[:, None, :], -jnp.inf, ranked)
    _, idx = jax.lax.top_k(ranked, keep)                           # [B,Hk,keep]
    idx = jnp.sort(idx, axis=-1)                                   # keep order

    # pad winners (possible only when length < keep) become inert slots
    keep_n = jnp.minimum(length, keep)                             # [B]
    slot_ok = jnp.arange(keep)[None, None, :] < keep_n[:, None, None]

    def gather(x):  # [B,Hk,N,*] → [B,Hk,keep,*] (zeroed at inert slots)
        y = jnp.take_along_axis(x, idx[..., None], axis=2)
        return jnp.where(slot_ok[..., None], y, 0) if bucketed else y

    slot_pad = s - keep
    kq, kscale, vscale = cache.kq, cache.kscale, cache.vscale
    if cache.quantized_kv:
        kc, ks = quant.quantize(gather(k_full), 8)
        k = jnp.pad(kc, ((0, 0), (0, 0), (0, slot_pad), (0, 0)))
        kscale = jnp.pad(ks, ((0, 0), (0, 0), (0, slot_pad)))
        v = cache.v
        if v is not None:
            vc, vs = quant.quantize(gather(v_full), 8)
            v = jnp.pad(vc, ((0, 0), (0, 0), (0, slot_pad), (0, 0)))
            vscale = jnp.pad(vs, ((0, 0), (0, 0), (0, slot_pad)))
    else:
        k_sel = gather(k_full).astype(cache.k.dtype)
        k = jnp.pad(k_sel, ((0, 0), (0, 0), (0, slot_pad), (0, 0)))
        v = cache.v
        if v is not None:
            v_sel = gather(v_full).astype(v.dtype)
            v = jnp.pad(v_sel, ((0, 0), (0, 0), (0, slot_pad), (0, 0)))
        if kq is not None:
            qn, sn = quant.quantize(k_sel, prune.score_bits)
            kq = jnp.pad(qn, ((0, 0), (0, 0), (0, slot_pad), (0, 0)))
            kscale = jnp.pad(sn, ((0, 0), (0, 0), (0, slot_pad)))

    acc_sel = jnp.take_along_axis(acc_scores, idx, axis=2)
    valid_sel = jnp.broadcast_to(slot_ok, (b, hk, keep))
    acc_sel = jnp.where(valid_sel, acc_sel, 0.0)
    pos_sel = jnp.where(valid_sel, idx, -1)
    acc = jnp.pad(acc_sel.astype(jnp.float32), ((0, 0), (0, 0), (0, slot_pad)))
    valid = jnp.pad(valid_sel, ((0, 0), (0, 0), (0, slot_pad)))
    pos = jnp.pad(pos_sel.astype(jnp.int32), ((0, 0), (0, 0), (0, slot_pad)),
                  constant_values=-1)
    return cache._replace(
        k=k, v=v, kq=kq, kscale=kscale, vscale=vscale, acc=acc, valid=valid,
        pos=pos, fill=keep_n.astype(jnp.int32),
        step=length.astype(jnp.int32))
