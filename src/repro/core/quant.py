"""Signed multibit quantization — the TPU analogue of UniCAIM's FeFET cell.

The paper stores keys in 1–3-bit signed FeFET levels (Fig. 5/6) and encodes
queries via "bitwise expansion" (Fig. 6c). On TPU this becomes symmetric
signed integer quantization with a per-(token, head) scale:

    q  = round(clip(x / s, -qmax, qmax)),   s = max|x| / qmax

stored in an int8 container (optionally packed two-per-byte for 4-bit).
1-bit degenerates to sign(x) with s = mean|x| (the paper's ±1 cell).

All functions are shape-polymorphic over leading dims and quantize along the
last axis (the head_dim a CAM row spans).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def qmax_for_bits(bits: int) -> int:
    """Largest representable magnitude for `bits`-bit signed symmetric."""
    if bits == 1:
        return 1
    return 2 ** (bits - 1) - 1


def quantize(x: jax.Array, bits: int):
    """Quantize along the last axis.

    Returns (q: int8 of x.shape, scale: f32 of x.shape[:-1]) with
    dequant(q, scale) ≈ x.
    """
    xf = x.astype(jnp.float32)
    if bits == 1:
        # paper's ±1 cell: complementary V_TH pair; scale = E|x| minimises L2
        scale = jnp.mean(jnp.abs(xf), axis=-1)
        q = jnp.where(xf >= 0, 1, -1).astype(jnp.int8)
        return q, scale
    qm = qmax_for_bits(bits)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / qm
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / safe[..., None]), -qm, qm).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def quantize_query(x: jax.Array, bits: int):
    """Query-side 'bitwise expansion' (paper Fig. 6c) == signed quantization.

    Kept as a distinct entry point because the paper drives queries onto
    bit-lines with a different encoding than the stored keys; numerically it
    is the same symmetric mapping.
    """
    return quantize(x, bits)


# ---------------------------------------------------------------------------
# int4 packing — the byte-accounting (and Pallas kernel) representation.
# Two 4-bit codes per int8 byte; even index in the low nibble.
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] along the last axis (must be even)."""
    assert q.shape[-1] % 2 == 0, "pack_int4 needs an even last axis"
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF
    hi = (q[..., 1::2].astype(jnp.uint8) & 0xF) << 4
    return (lo | hi).astype(jnp.uint8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of pack_int4 → int8 codes with sign extension."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


def mirror_bytes_per_token(head_dim: int, bits: int) -> int:
    """HBM bytes of the quantized key mirror per (token, kv-head), at the
    production packing density (1-bit: 8/byte, 2-bit: 4/byte, 3-4 bit:
    nibble-packed, 5-8 bit: int8). +4 bytes for the f32 scale. The CPU
    reference cache stores an int8 container; pack_int4 provides the packed
    layout the TPU kernels consume."""
    if bits == 1:
        return -(-head_dim // 8) + 4
    if bits == 2:
        return -(-head_dim // 4) + 4
    if bits <= 4:
        return head_dim // 2 + 4
    return head_dim + 4


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_packed(x: jax.Array, bits: int):
    """quantize + pack when bits<=4 (framework storage path)."""
    q, s = quantize(x, bits)
    if bits <= 4:
        return pack_int4(q), s
    return q, s
