"""UniCAIM core: static-dynamic KV cache pruning as composable JAX modules."""
from repro.core.attention import chunked_causal_attention, decode_attention
from repro.core.cache import KVCache, init_cache, prefill_fill, write_token
from repro.core.pruning import memory_footprint_bytes, prefill_and_prune

__all__ = [
    "KVCache", "init_cache", "write_token", "prefill_fill",
    "decode_attention", "chunked_causal_attention",
    "prefill_and_prune", "memory_footprint_bytes",
]
