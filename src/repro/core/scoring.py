"""Approximate similarity scoring — the CAM mode of UniCAIM (§III-B.3).

The analog CAM evaluates q·Kᵀ over low-bit signed cells in one discharge;
here the same contraction runs as an integer matmul over the quantized key
mirror, producing scores for ALL slots at a fraction of the bf16 bytes:

    score[b,h,s] = (Σ_d qq[b,h,d]·kq[b,h,s,d]) · qscale[b,h] · kscale[b,h,s]

The charge-domain accumulation (§III-B.4) — C_SL charge-sharing onto C_Acc in
the same cycle — becomes a fused update of the per-slot accumulated-score
table with the softmax-normalised approximate probabilities.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topk import NEG_INF


def approx_scores(qq: jax.Array, qscale: jax.Array,
                  kq: jax.Array, kscale: jax.Array,
                  valid: jax.Array) -> jax.Array:
    """Quantized approximate attention scores.

    qq:     [B, Hq, d]     int8 quantized query (one decode step)
    qscale: [B, Hq]        f32
    kq:     [B, Hk, S, d]  int8 quantized key mirror
    kscale: [B, Hk, S]     f32
    valid:  [B, Hk, S]     bool
    returns [B, Hq, S] f32 scores, NEG_INF at invalid slots.
    """
    b, hq, d = qq.shape
    _, hk, s, _ = kq.shape
    group = hq // hk
    qq_g = qq.reshape(b, hk, group, d)
    # integer contraction (MXU int8 path on TPU), then scale in f32
    raw = jax.lax.dot_general(
        qq_g.astype(jnp.int32), kq.astype(jnp.int32),
        dimension_numbers=(((3,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.int32,
    )  # [B, Hk, G, S]
    scores = (raw.astype(jnp.float32)
              * qscale.reshape(b, hk, group)[..., None]
              * kscale[:, :, None, :])
    scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
    return scores.reshape(b, hq, s)


def exact_scores(q: jax.Array, k: jax.Array, valid: jax.Array) -> jax.Array:
    """Full-precision scores (H2O baseline / accuracy reference).

    q: [B, Hq, d], k: [B, Hk, S, d], valid: [B, Hk, S] → [B, Hq, S].
    """
    b, hq, d = q.shape
    _, hk, s, _ = k.shape
    group = hq // hk
    q_g = q.reshape(b, hk, group, d).astype(jnp.float32)
    raw = jnp.einsum("bhgd,bhsd->bhgs", q_g, k.astype(jnp.float32))
    raw = jnp.where(valid[:, :, None, :], raw, NEG_INF)
    return raw.reshape(b, hq, s)


def score_probs(scores: jax.Array, head_dim: int) -> jax.Array:
    """Masked softmax over slots: scores [B, Hq, S] → probs [B, Hq, S]."""
    logits = scores / jnp.sqrt(jnp.float32(head_dim))
    logits = logits - jax.lax.stop_gradient(jnp.max(logits, axis=-1,
                                                    keepdims=True))
    e = jnp.exp(logits) * (scores > NEG_INF / 2)
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(z, 1e-30)


def accumulate(acc: jax.Array, probs: jax.Array, n_kv_heads: int,
               decay: float = 1.0) -> jax.Array:
    """Charge-domain accumulation: fold this step's probabilities into the
    per-(kv-head, slot) accumulated-score table.

    acc:   [B, Hk, S] f32 running table
    probs: [B, Hq, S] f32 this step's (approximate) attention probabilities
    """
    b, hq, s = probs.shape
    group = hq // n_kv_heads
    step = probs.reshape(b, n_kv_heads, group, s).sum(axis=2)
    return acc * decay + step
