"""UniCAIM attention — the three computation modes composed (§III-B).

Decode step:  CAM mode (approximate scoring over the quantized mirror)
              → top-k selection → current-domain mode (exact attention over
              the gathered k tokens) → charge-domain mode (accumulated-score
              update) → static eviction on the next write.

Prefill:      chunked causal attention (flash-style online softmax in XLA,
              Pallas kernel on TPU) that produces per-token accumulated
              attention column sums "for free" → one-shot static pruning.

All paths are pure functions: (cache, inputs) → (cache, outputs), so the
decode loop is a lax.scan and the whole model jits/shards with pjit.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import xscan

from repro.configs.base import PruneConfig
from repro.core import quant, scoring, topk
from repro.core.cache import (KVCache, _token_writes, layer_window,
                              protected_mask, slot_window,
                              slot_window_merge, write_token,
                              write_token_stacked)
from repro.core.topk import NEG_INF
from repro.runtime.sharding import shard


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _dense_attend(cache: KVCache, q: jax.Array, head_dim_scale: int,
                  mask: Optional[jax.Array] = None):
    """Exact attention over all (valid) cache slots.

    q: [B, Hq, d] → out [B, Hq, dv]; also returns probs [B, Hq, S].
    """
    s_exact = scoring.exact_scores(q, cache.k_values(), cache.valid)
    if mask is not None:
        s_exact = jnp.where(mask, s_exact, NEG_INF)
    probs = scoring.score_probs(s_exact, head_dim_scale)          # [B,Hq,S]
    b, hq, s = probs.shape
    hk = cache.k.shape[1]
    g = hq // hk
    p = probs.reshape(b, hk, g, s)
    out = jnp.einsum("bhgs,bhsd->bhgd", p,
                     cache.v_values().astype(jnp.float32))
    return out.reshape(b, hq, -1), probs


def _gathered_attend_blocked(cache: KVCache, q: jax.Array, idx: jax.Array,
                             head_dim_scale: int):
    """Exact attention over block-local top-k slots (distributed CAM race).

    idx: [B, Hk, nb, k_loc] — per-block winners. All gathers index the
    UNSHARDED intra-block axis, so with slots sharded over `model` and
    blocks aligned to shards, no cache bytes cross the interconnect; only
    the [B, Hq] softmax stats and the [B, Hq, dv] partial outputs reduce.
    """
    b, hq, d = q.shape
    _, hk, nb, k_loc = idx.shape
    g = hq // hk
    s = cache.k.shape[2]
    # re-pin shardings: reshape splits the sharded slot axis into
    # (blocks, slots/blocks) — the constraint keeps blocks on `model` so
    # the gathers below stay shard-local (no cache all-gather)
    kb = shard(cache.k.reshape(b, hk, nb, s // nb, d),
               "batch", "kv_heads", "slots", None, None)
    vb = shard(cache.v.reshape(b, hk, nb, s // nb, -1),
               "batch", "kv_heads", "slots", None, None)
    validb = shard(cache.valid.reshape(b, hk, nb, s // nb),
                   "batch", "kv_heads", "slots", None)
    k_sel = jnp.take_along_axis(kb, idx[..., None], axis=3)   # [B,Hk,nb,kl,d]
    v_sel = jnp.take_along_axis(vb, idx[..., None], axis=3)
    valid_sel = jnp.take_along_axis(validb, idx, axis=3)
    if cache.quantized_kv:
        ks_b = cache.kscale.reshape(b, hk, nb, s // nb)
        vs_b = cache.vscale.reshape(b, hk, nb, s // nb)
        k_sel = (k_sel.astype(jnp.float32)
                 * jnp.take_along_axis(ks_b, idx, axis=3)[..., None])
        v_sel = (v_sel.astype(jnp.float32)
                 * jnp.take_along_axis(vs_b, idx, axis=3)[..., None])
    q_g = q.reshape(b, hk, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhnkd->bhgnk", q_g,
                        k_sel.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(head_dim_scale))
    logits = jnp.where(valid_sel[:, :, None, :, :], logits, NEG_INF)
    m = jnp.max(logits, axis=(-2, -1), keepdims=True)         # cross-block
    e = jnp.exp(logits - jax.lax.stop_gradient(m))
    e = e * (logits > NEG_INF / 2)
    z = jnp.sum(e, axis=(-2, -1), keepdims=True)
    p = e / jnp.maximum(z, 1e-30)
    out = jnp.einsum("bhgnk,bhnkd->bhgd", p, v_sel.astype(jnp.float32))
    return out.reshape(b, hq, -1)


def _gathered_attend(cache: KVCache, q: jax.Array, idx: jax.Array,
                     head_dim_scale: int):
    """Exact attention over gathered top-k slots (current-domain CIM).

    q: [B, Hq, d]; idx: [B, Hk, k] slot indices → out [B, Hq, dv].
    """
    b, hq, d = q.shape
    _, hk, k = idx.shape
    g = hq // hk
    k_sel = jnp.take_along_axis(cache.k, idx[..., None], axis=2)   # [B,Hk,k,d]
    v_sel = jnp.take_along_axis(cache.v, idx[..., None], axis=2)
    valid_sel = jnp.take_along_axis(cache.valid, idx, axis=2)      # [B,Hk,k]
    if cache.quantized_kv:
        k_sel = (k_sel.astype(jnp.float32)
                 * jnp.take_along_axis(cache.kscale, idx, axis=2)[..., None])
        v_sel = (v_sel.astype(jnp.float32)
                 * jnp.take_along_axis(cache.vscale, idx, axis=2)[..., None])
    q_g = q.reshape(b, hk, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", q_g, k_sel.astype(jnp.float32))
    logits = jnp.where(valid_sel[:, :, None, :], logits, NEG_INF)
    probs = scoring.score_probs(logits.reshape(b, hq, k), head_dim_scale)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs.reshape(b, hk, g, k),
                     v_sel.astype(jnp.float32))
    return out.reshape(b, hq, -1), probs, valid_sel


def _slot_axes(mesh, nb: int):
    """Greedy prefix of (model, data, pod) whose sizes multiply to nb."""
    axes, prod = [], 1
    for a in ("model", "data", "pod"):
        if a in mesh.shape and prod < nb:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) if prod == nb else ()


def _blocked_attend_shardmap(cache: KVCache, q: jax.Array,
                             biased: jax.Array, prune: PruneConfig,
                             mesh) -> jax.Array:
    """Shard-local top-k + gather + flash-decode combine via shard_map.

    The production path for slot-sharded caches: each model-shard races its
    LOCAL slots (k/select_blocks winners), gathers locally, and only the
    softmax stats + [B,Hq,dv] partial outputs cross the interconnect — the
    distributed form of the paper's per-array CAM race. Requires
    select_blocks == mesh model-axis size.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compat import shard_map

    b, hq, d = q.shape
    hk = cache.k.shape[1]
    g = hq // hk
    nb = prune.select_blocks
    k_loc = prune.select_k // nb
    slot_axes = _slot_axes(mesh, nb)
    assert slot_axes, (dict(mesh.shape), nb)
    red = slot_axes if len(slot_axes) > 1 else slot_axes[0]
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.shape and a not in slot_axes
                       and b % mesh.shape[a] == 0)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    sspec = slot_axes if len(slot_axes) > 1 else slot_axes[0]
    quantized = cache.quantized_kv

    def local_fn(q_l, k_l, v_l, ks_l, vs_l, valid_l, sc_l):
        _, idx = jax.lax.top_k(sc_l, k_loc)
        k_sel = jnp.take_along_axis(k_l, idx[..., None], axis=2)
        v_sel = jnp.take_along_axis(v_l, idx[..., None], axis=2)
        if quantized:
            k_sel = (k_sel.astype(jnp.float32)
                     * jnp.take_along_axis(ks_l, idx, axis=2)[..., None])
            v_sel = (v_sel.astype(jnp.float32)
                     * jnp.take_along_axis(vs_l, idx, axis=2)[..., None])
        valid_sel = jnp.take_along_axis(valid_l, idx, axis=2)
        q_g = q_l.reshape(-1, hk, g, d).astype(jnp.float32)
        logits = jnp.einsum("bhgd,bhkd->bhgk", q_g,
                            k_sel.astype(jnp.float32))
        logits = logits / jnp.sqrt(jnp.float32(d))
        logits = jnp.where(valid_sel[:, :, None, :], logits, NEG_INF)
        m = jax.lax.pmax(jnp.max(logits, -1, keepdims=True), red)
        e = jnp.exp(logits - m) * (logits > NEG_INF / 2)  # [b,Hk,g,k_loc]
        z = jax.lax.psum(jnp.sum(e, axis=-1), red)        # [b,Hk,g]
        o = jnp.einsum("bhgk,bhkd->bhgd", e, v_sel.astype(jnp.float32))
        o = jax.lax.psum(o, red)
        return o / jnp.maximum(z, 1e-30)[..., None]

    dummy = jnp.zeros((), jnp.float32)
    ks_in = cache.kscale if quantized else dummy
    vs_in = cache.vscale if quantized else dummy
    scalar = P()
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None),                    # q
                  P(bspec, None, sspec, None),             # k
                  P(bspec, None, sspec, None),             # v
                  P(bspec, None, sspec) if quantized else scalar,
                  P(bspec, None, sspec) if quantized else scalar,
                  P(bspec, None, sspec),                   # valid
                  P(bspec, None, sspec)),                  # scores
        out_specs=P(bspec, None, None, None),
        check_vma=False,
    )(q, cache.k, cache.v, ks_in, vs_in, cache.valid, biased)
    return out.reshape(b, hq, -1)


def fused_auto_decision() -> dict:
    """How `PruneConfig(fused="auto")` resolves on this backend, with the
    measured rationale (benches record this into BENCH_latency.json).

    The fused engine's advantage is the Pallas kernel's winner-only DMA
    gather — the unselected K/V rows never leave HBM. Off-TPU the kernel
    lowers to the XLA fallback (`ref.fused_decode_ref`), whose gather
    offers no such bandwidth win: interleaved min-time profiling at
    ctx512 put it at parity-to-~6%-slower than the composed three-pass
    path (identical FLOPs/bytes per XLA cost analysis; the historical
    1.3x figure was sequential-median timing noise). auto therefore runs
    fused only where the kernel is real."""
    on_tpu = jax.default_backend() == "tpu"
    return {
        "engine": "fused" if on_tpu else "composed",
        "backend": jax.default_backend(),
        "reason": ("pallas kernel: winner-only DMA gather pays on TPU"
                   if on_tpu else
                   "xla fallback measured at parity-to-slower vs the "
                   "composed path off-TPU (no DMA-gather advantage)"),
    }


def _fused_enabled(prune: PruneConfig) -> bool:
    if prune.fused == "auto":
        return fused_auto_decision()["engine"] == "fused"
    return bool(prune.fused)


def _fused_eligible(cache: KVCache, prune: PruneConfig) -> bool:
    """The fused engine covers the paper-default decode configuration;
    anything it doesn't (threshold race, exact accumulation, MLA latent
    caches, slot-sharded meshes) falls back to the composed oracle path."""
    if not (_fused_enabled(prune) and prune.policy == "unicaim"):
        return False
    if prune.select_mode != "topk" or prune.accumulate != "approx":
        return False
    if cache.v is None:                       # MLA latent cache
        return False
    nb = max(1, prune.select_blocks)
    if prune.select_k % nb:
        return False
    from repro.runtime.sharding import active_mesh
    # under any mesh the composed path owns distribution (shard constraint
    # re-pinning / the shard_map race); the fused kernel is unsharded and
    # would force GSPMD to all-gather the cache around the pallas_call
    return active_mesh() is None


def _fused_decode_attend(cache: KVCache, q: jax.Array, prune: PruneConfig
                         ) -> Tuple[KVCache, jax.Array]:
    """Single-pass fused engine: one kernel (or one fused XLA region) does
    CAM scoring over the mirror, block-local selection, winner-only
    gather, exact attention, AND emits the charge-domain accumulation
    probabilities — no [B,Hq,S] scores or index tensors between passes."""
    from repro.kernels import ops

    b, hq, d = q.shape
    hk = cache.k.shape[1]
    g = hq // hk
    s = cache.slots
    dv = cache.v.shape[-1]
    qq, qs = quant.quantize_query(q, prune.query_bits)
    mirror = cache.kq if cache.kq is not None else cache.k
    if cache.quantized_kv:
        kscale, vscale = cache.kscale, cache.vscale
    else:
        kscale = jnp.ones((b, hk, s), jnp.float32)
        vscale = kscale
    prot = protected_mask(cache, prune)

    def bhf(x):                               # [B, Hk, ...] → [B·Hk, ...]
        return x.reshape((b * hk,) + x.shape[2:])

    nb = max(1, prune.select_blocks)
    # per-lane live counts drive the ragged kernel's early exit (global
    # selection only — a block race would change per-block winner counts)
    fills = jnp.repeat(cache.fill, hk) if nb == 1 else None
    out, probs = ops.fused_decode(
        q.reshape(b, hk, g, d).reshape(b * hk, g, d),
        qq.reshape(b, hk, g, d).reshape(b * hk, g, d),
        qs.reshape(b * hk, g),
        bhf(mirror), bhf(cache.kscale), bhf(kscale), bhf(vscale),
        bhf(cache.valid.astype(jnp.int8)), bhf(prot.astype(jnp.int8)),
        bhf(cache.k), bhf(cache.v),
        select_k=prune.select_k, num_blocks=nb,
        backend=prune.fused_backend, fills=fills)
    out = out.reshape(b, hk, g, dv).reshape(b, hq, dv)
    acc = cache.acc * prune.acc_decay + probs.reshape(b, hk, s)
    return cache._replace(acc=acc), out


def windowed_decode_attention(cache: KVCache, q: jax.Array,
                              k_new: jax.Array, v_new: jax.Array,
                              prune: PruneConfig, window: Optional[int],
                              ) -> Tuple[KVCache, jax.Array]:
    """One decode step over the `[:window]` slot prefix of the cache.

    `window` is a STATIC width (the caller picks it on the host from the
    lane fills — see `cache.decode_window`); None or >= slots runs the
    full-width step. Because every live slot sits in the fill prefix and
    slots >= fill are invalid (NEG_INF-scored, zero-probability,
    zero-accumulation), the windowed step is bit-identical to the
    full-width one while touching O(window) instead of O(slots) bytes —
    the decode-cost-tracks-live-context contract of the paper."""
    if window is None or window >= cache.slots:
        return decode_attention(cache, q, k_new, v_new, prune)
    win, out = decode_attention(slot_window(cache, window), q, k_new,
                                v_new, prune)
    return slot_window_merge(cache, win), out


def decode_attention(cache: KVCache, q: jax.Array, k_new: jax.Array,
                     v_new: jax.Array, prune: PruneConfig,
                     ) -> Tuple[KVCache, jax.Array]:
    """One decode step of UniCAIM (or baseline policy) attention.

    q:     [B, Hq, d] current query (post-RoPE)
    k_new: [B, Hk, d], v_new: [B, Hk, dv] current token (post-RoPE)
    returns (updated cache, attention output [B, Hq, dv] f32).
    """
    cache = write_token(cache, k_new, v_new, prune)
    return _policy_attend(cache, q, prune)


def _policy_attend(cache: KVCache, q: jax.Array, prune: PruneConfig,
                   ) -> Tuple[KVCache, jax.Array]:
    """Post-write half of a decode step: policy dispatch (dense / h2o /
    unicaim score→select→attend, fused or composed) + charge-domain
    accumulation. Shared verbatim by the functional `decode_attention`
    and the in-place `decode_attention_stacked` (which hands it a
    windowed read VIEW of the stacked cache), so both paths are the same
    arithmetic — the basis of their bitwise parity."""
    head_dim = q.shape[-1]

    if prune.policy in ("dense", "streaming"):
        out, _ = _dense_attend(cache, q, head_dim)
        return cache, out

    if prune.policy == "h2o":
        out, probs = _dense_attend(cache, q, head_dim)
        acc = scoring.accumulate(cache.acc, probs, cache.k.shape[1],
                                 prune.acc_decay)
        return cache._replace(acc=acc), out

    # ---- unicaim ----
    if _fused_eligible(cache, prune):
        return _fused_decode_attend(cache, q, prune)

    b, hq, _ = q.shape
    hk = cache.k.shape[1]
    # CAM mode: approximate scores over the quantized mirror (in int8-KV
    # mode the stored K itself is the mirror — no second copy)
    qq, qs = quant.quantize_query(q, prune.query_bits)
    mirror = cache.kq if cache.kq is not None else cache.k
    s_approx = scoring.approx_scores(qq, qs, mirror, cache.kscale,
                                     cache.valid)                  # [B,Hq,S]
    grouped = topk.gqa_group_scores(s_approx, hk)                  # [B,Hk,S]
    prot = protected_mask(cache, prune)

    if prune.select_mode == "threshold":
        # CAM race semantics: masked exact attention, no gather. The race
        # runs over finite *evictable* scores only — protected slots (the
        # ±1e30 sentinels of apply_selection_bias) would blow the binary
        # search's resolution out to ~1e27 — and the protected mask is
        # unioned back in, with the per-row target shrunk accordingly.
        evictable = cache.valid & ~prot
        k_dyn = jnp.maximum(
            prune.select_k - jnp.sum(prot, axis=-1, keepdims=True), 1)
        mask = topk.threshold_race(grouped, k_dyn, prune.threshold_iters,
                                   eligible=evictable) | prot      # [B,Hk,S]
        g = hq // hk
        mask_q = jnp.repeat(mask, g, axis=1) if g > 1 else mask
        out, _ = _dense_attend(cache, q, head_dim, mask=mask_q)
    elif prune.select_blocks > 1:
        biased = topk.apply_selection_bias(grouped, prot, ~cache.valid)
        nb = prune.select_blocks
        s = biased.shape[-1]
        assert s % nb == 0 and prune.select_k % nb == 0, (s, prune.select_k)
        from repro.runtime.sharding import active_mesh
        mesh = active_mesh()
        if mesh is not None and _slot_axes(mesh, nb):
            # production path: shard_map keeps select+gather+attend local
            out = _blocked_attend_shardmap(cache, q, biased, prune, mesh)
        else:
            k_loc = prune.select_k // nb
            biased_b = shard(biased.reshape(b, hk, nb, s // nb),
                             "batch", "kv_heads", "slots", None)
            _, idx = topk.exact_topk(biased_b, k_loc)    # [B,Hk,nb,k_loc]
            out = _gathered_attend_blocked(cache, q, idx, head_dim)
    else:
        biased = topk.apply_selection_bias(grouped, prot, ~cache.valid)
        _, idx = topk.exact_topk(biased, prune.select_k)           # [B,Hk,k]
        out, _, _ = _gathered_attend(cache, q, idx, head_dim)

    # charge-domain mode: same-cycle accumulation of approximate probs
    if prune.accumulate == "approx":
        probs_acc = scoring.score_probs(s_approx, head_dim)
    else:  # 'exact' — full-precision probabilities (ablation)
        s_exact = scoring.exact_scores(q, cache.k, cache.valid)
        probs_acc = scoring.score_probs(s_exact, head_dim)
    acc = scoring.accumulate(cache.acc, probs_acc, hk, prune.acc_decay)
    return cache._replace(acc=acc), out


def decode_attention_stacked(kv: KVCache, li, q: jax.Array,
                             k_new: jax.Array, v_new: jax.Array,
                             prune: PruneConfig, window: Optional[int],
                             active: Optional[jax.Array],
                             ) -> Tuple[KVCache, jax.Array]:
    """One IN-PLACE decode step at layer `li` of a layer-stacked cache.

    The zero-copy split of `windowed_decode_attention`: reads go through
    a `dynamic_slice` window VIEW of layer `li` (`layer_window` — pure
    reads, aliasing-safe), writes go straight into the full-width stacked
    buffers as O(B·Hk·dh) scatters plus one O(window) `dynamic_update_
    slice` for the accumulated-score row — never the per-field
    slice-copy + merge round-trip that defeats `donate_argnums`. `kv`
    threads through the caller's layer scan as a CARRY, so under jit the
    whole DecodeState stays input-output aliased across the decode block.

    `active` ([B] bool, optional) freezes finished lanes at the source
    (dropped scatters + kept acc rows) — replacing the full-width
    `state_lane_select` merge of the masked decode block. Active-lane
    arithmetic is `_policy_attend` over the same windowed values the
    functional path sees, hence bitwise-identical outputs; inactive
    lanes' out rows are garbage the caller already masks.

    q: [B, Hq, d]; k_new/v_new: [B, Hk, ·]; window as in
    `windowed_decode_attention` (None = full width — eviction/ring-wrap
    lanes included, since `layer_window` then views every slot).
    Returns (updated stacked cache, out [B, Hq, dv])."""
    w = kv.slots if window is None or window >= kv.slots else window
    view = layer_window(kv, li, w)
    slot, vals = _token_writes(view, k_new, v_new, prune)
    # mirror the token write into the view (all lanes, matching the
    # functional path — inactive lanes' results never land anywhere)
    b, hk = slot.shape
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(hk)[None, :]
    acc0 = view.acc
    view = view._replace(
        **{f: getattr(view, f).at[bi, hi, slot].set(v)
           for f, v in vals.items()},
        fill=jnp.minimum(view.fill + 1, w), step=view.step + 1)
    view, out = _policy_attend(view, q, prune)
    acc_row = view.acc
    if active is not None:
        acc_row = jnp.where(active[:, None, None], acc_row, acc0)
    # Storage writes LAST, with the scatter index carrying a zero-valued
    # data dependency on the attend output. This is load-bearing for the
    # in-place guarantee: the attend's window reads of the stacked
    # buffers are dataflow-independent of the scatters, and XLA's
    # scheduler is free to place an in-place-aspiring scatter BEFORE a
    # read of the same buffer — copy-insertion then preserves the old
    # value with a full O(slots) carry copy per step, silently
    # resurrecting the copy floor (measured: ~8 MB/step temp at
    # slots=4096; `lax.optimization_barrier` does NOT fix the schedule).
    # Routing `dep == 0` (guaranteed: nan_to_num maps the NaN/Inf edge
    # of 0.0*x to 0.0, and the runtime dependency keeps the product from
    # constant-folding) through the scatter index forces every read to
    # complete first, keeping compiled temp bytes flat in `slots`.
    dep = jnp.nan_to_num(0.0 * (jnp.sum(out) + jnp.sum(acc_row))
                         ).astype(jnp.int32)
    kv = write_token_stacked(kv, li, slot + dep,
                             {f: v for f, v in vals.items() if f != "acc"},
                             active)
    li = jnp.asarray(li, jnp.int32) + dep
    acc = jax.lax.dynamic_update_slice(kv.acc, acc_row[None],
                                       (li, 0, 0, 0))
    return kv._replace(acc=acc), out


# ---------------------------------------------------------------------------
# Prefill: chunked causal attention + accumulated column scores
# ---------------------------------------------------------------------------


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             chunk: int = 512, obs_window: int = 0,
                             scale: Optional[float] = None,
                             length: Optional[jax.Array] = None,
                             ) -> Tuple[jax.Array, jax.Array]:
    """Causal attention over the full prompt, scanned over query chunks.

    q: [B, Hq, N, d], k/v: [B, Hk, N, d] → (out [B, Hq, N, dv],
    acc [B, Hk, N] column sums of attention probabilities).

    obs_window > 0 restricts accumulation to the last `obs_window` query rows
    (SnapKV-style); 0 accumulates over all rows (H2O-style, paper default).
    Never materialises the N×N matrix — one [*, chunk, N] tile at a time.

    `length` ([B] int32, optional) marks the true per-lane prompt length
    when the input is right-padded to a shape-stable bucket: rows at or
    beyond `length` never accumulate into the column sums (so pad tokens
    add zero charge-domain mass), the observation window anchors at the
    true length, and pad *columns* are already unreachable for every real
    row via the causal mask (pads sit at the end). Outputs at pad rows are
    garbage and must be ignored by the caller. With `length=None` the full
    width is live — bit-identical to the unbucketed behaviour.
    """
    b, hq, n, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    chunk = min(chunk, n)
    n_real = n
    pad = (-n) % chunk
    if pad:
        # pad rows/cols at the END: causal masking kills pad columns for
        # every real row; pad-row outputs are sliced off below
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        n = n + pad
    n_chunks = n // chunk
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if length is None:
        length = jnp.full((b,), n_real, jnp.int32)
    length = jnp.minimum(length.astype(jnp.int32), n_real)
    # K/V stay in their storage dtype (bf16 in production) — the MXU
    # accumulates in f32 via preferred_element_type; re-reading full K/V per
    # chunk at 2 bytes instead of 4 halves the dominant HBM term (§Perf)
    q = q.astype(k.dtype)
    q_chunks = q.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    col = jnp.arange(n)

    def body(acc, inp):
        ci, q_c = inp                                              # [B,Hq,T,d]
        row = ci * chunk + jnp.arange(chunk)
        q_g = q_c.reshape(b, hk, g, chunk, d)
        logits = jax.lax.dot_general(
            q_g, k, dimension_numbers=(((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)                    # [B,Hk,g,T,N]
        logits = logits.reshape(b, hq, chunk, n)
        causal = row[:, None] >= col[None, :]
        logits = jnp.where(causal[None, None], logits * scale, NEG_INF)
        m = jnp.max(logits, axis=-1, keepdims=True)
        e = jnp.exp(logits - m)
        z = jnp.sum(e, axis=-1, keepdims=True)
        probs = e / jnp.maximum(z, 1e-30)                          # [B,Hq,T,N]
        p_g = probs.reshape(b, hk, g, chunk, n).astype(v.dtype)
        out_c = jax.lax.dot_general(
            p_g, v, dimension_numbers=(((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)                    # [B,Hk,g,T,dv]
        live = row[None, :] < length[:, None]   # pad rows add no mass
        if obs_window > 0:
            live = live & (row[None, :] >= (length[:, None] - obs_window))
        w = jnp.where(live, 1.0, 0.0)[:, None, None, :, None]
        acc = acc + jnp.sum(p_g.astype(jnp.float32) * w, axis=(2, 3))
        return acc, out_c.reshape(b, hq, chunk, -1)

    acc0 = jnp.zeros((b, hk, n), jnp.float32)
    acc, outs = xscan(body, acc0, (jnp.arange(n_chunks), q_chunks))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, n, -1)
    return out[:, :, :n_real], acc[:, :, :n_real]


def prefill_chunk_attend(q_c: jax.Array, k_buf: jax.Array, v_buf: jax.Array,
                         row0: jax.Array, length: jax.Array,
                         scale: Optional[float] = None,
                         obs_window: int = 0,
                         ) -> Tuple[jax.Array, jax.Array]:
    """One prompt chunk attending into the streamed prefill K/V buffer.

    The chunked-prefill (Sarathi-style admission) analogue of one `body`
    pass of `chunked_causal_attention`: queries for absolute rows
    [row0, row0+C) attend causally over the whole fixed-size buffer
    [B, Hk, N, ·] whose first row0+C rows have been written. Unwritten
    columns sit strictly in the causal future of every chunk row, so the
    causal mask alone keeps them out — the computation is bit-identical to
    the same rows of a whole-prompt `chunked_causal_attention` with
    chunk=C over the same bucket N (same reduction widths, same masked
    exponentials), which is what makes time-sliced admission numerically
    invisible.

    q_c: [B, Hq, C, d]; k_buf/v_buf: [B, Hk, N, ·]; row0: scalar int32
    (may be traced — one compiled program per (C, N) pair); length: [B]
    true prompt lengths. Returns (out [B, Hq, C, dv], col_acc [B, Hk, N]
    — this chunk's contribution to the accumulated column sums).
    """
    b, hq, c, d = q_c.shape
    hk, n = k_buf.shape[1], k_buf.shape[2]
    g = hq // hk
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q_c = q_c.astype(k_buf.dtype)
    row = row0 + jnp.arange(c)
    col = jnp.arange(n)
    q_g = q_c.reshape(b, hk, g, c, d)
    logits = jax.lax.dot_general(
        q_g, k_buf, dimension_numbers=(((4,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)                        # [B,Hk,g,C,N]
    logits = logits.reshape(b, hq, c, n)
    causal = row[:, None] >= col[None, :]
    logits = jnp.where(causal[None, None], logits * scale, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / jnp.maximum(z, 1e-30)                              # [B,Hq,C,N]
    p_g = probs.reshape(b, hk, g, c, n).astype(v_buf.dtype)
    out_c = jax.lax.dot_general(
        p_g, v_buf, dimension_numbers=(((4,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)                        # [B,Hk,g,C,dv]
    live = row[None, :] < length[:, None]
    if obs_window > 0:
        live = live & (row[None, :] >= (length[:, None] - obs_window))
    w = jnp.where(live, 1.0, 0.0)[:, None, None, :, None]
    col_acc = jnp.sum(p_g.astype(jnp.float32) * w, axis=(2, 3))
    return out_c.reshape(b, hq, c, -1), col_acc
