"""Top-k selection — TPU analogues of UniCAIM's CAM-mode race (§III-B.3).

Two selection mechanisms, both gated on the *approximate* scores:

  * exact_topk        — `jax.lax.top_k`: returns exactly k indices, feeding
                        the gather + exact-attention (current-domain) path.
  * threshold_race    — the CAM discharge race: a fixed number of
                        binary-search iterations on a score threshold so that
                        ~k entries stay "charged"; returns a boolean mask
                        (no sort, no gather — masked exact attention).

The paper's race is O(1) in wall-clock because all sense lines discharge in
parallel; on TPU both mechanisms are O(S) bandwidth on an [*, S] score tensor
that was already produced by the scoring pass, i.e. they are roofline-free
riders on the CAM-mode output (see DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def exact_topk(scores: jax.Array, k: int):
    """lax.top_k over the last axis → (values, indices [..., k])."""
    return jax.lax.top_k(scores, k)


def threshold_race(scores: jax.Array, k, iters: int = 8,
                   eligible: Optional[jax.Array] = None) -> jax.Array:
    """CAM-style selection: binary-search a threshold so ~k survive.

    Mirrors the I_Ref = (k+1)·I_dyn comparator: each iteration checks how
    many lines are still above threshold and tightens the reference.
    Returns a boolean mask over the last axis with >= 1 and ~k True entries.

    `k` may be an int or an int array broadcastable against the count
    ([..., 1]) — per-row targets when protected slots eat into the budget.

    `eligible` (optional [..., S] bool) restricts BOTH the search range and
    the returned mask to those entries. This matters when callers inject
    sentinel biases (±1e30 from `apply_selection_bias`): a binary search
    over [-1e30, 1e30] has ~1e27 resolution after 8 halvings, so every
    finite score lands in one bucket and the race degenerates to
    keep-everything. Racing only the finite, evictable scores keeps the
    threshold resolution at the scale of the actual score distribution;
    the caller unions the protected mask back in afterwards.
    """
    if eligible is None:
        lo = jnp.min(scores, axis=-1, keepdims=True)
        hi = jnp.max(scores, axis=-1, keepdims=True)
    else:
        lo = jnp.min(jnp.where(eligible, scores, jnp.inf), -1, keepdims=True)
        hi = jnp.max(jnp.where(eligible, scores, -jnp.inf), -1, keepdims=True)
        # no eligible entries → empty range; mask below comes out empty
        lo = jnp.where(jnp.isfinite(lo), lo, 0.0)
        hi = jnp.where(jnp.isfinite(hi), hi, 0.0)

    def count_ge(thr):
        ge = scores >= thr
        if eligible is not None:
            ge = ge & eligible
        return ge

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)
        count = jnp.sum(count_ge(mid), axis=-1, keepdims=True)
        # too many survivors -> raise threshold; too few -> lower it
        lo = jnp.where(count > k, mid, lo)
        hi = jnp.where(count > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    mask = count_ge(lo)
    # guarantee at least one survivor (the max always survives)
    top = count_ge(jnp.max(jnp.where(eligible, scores, -jnp.inf)
                           if eligible is not None else scores,
                           axis=-1, keepdims=True))
    return mask | top


def gqa_group_scores(scores: jax.Array, n_kv_heads: int) -> jax.Array:
    """Sum per-q-head scores within each GQA group → per-kv-head scores.

    scores: [..., Hq, S] → [..., Hk, S].  This is what the shared sense line
    per CAM row computes physically when a kv head serves a whole group.
    """
    *lead, hq, s = scores.shape
    assert hq % n_kv_heads == 0
    g = hq // n_kv_heads
    return scores.reshape(*lead, n_kv_heads, g, s).sum(axis=-2)


def apply_selection_bias(scores: jax.Array, protected: jax.Array,
                         invalid: jax.Array) -> jax.Array:
    """Protected slots always win the race; invalid slots never do."""
    scores = jnp.where(protected, jnp.float32(1e30), scores)
    return jnp.where(invalid, jnp.float32(NEG_INF), scores)


def indices_to_mask(indices: jax.Array, size: int) -> jax.Array:
    """[..., k] int indices → [..., size] boolean membership mask."""
    onehot = jax.nn.one_hot(indices, size, dtype=jnp.bool_)
    return jnp.any(onehot, axis=-2)
