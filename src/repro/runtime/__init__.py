from repro.runtime.sharding import (active_mesh, decode_state_pspecs,
                                    logical_to_spec, named_sharding,
                                    params_pspecs, params_shardings, shard,
                                    use_mesh)

__all__ = [
    "shard", "use_mesh", "active_mesh", "logical_to_spec", "named_sharding",
    "params_pspecs", "params_shardings", "decode_state_pspecs",
]
