"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Tensors are annotated with *logical* axis names; a rule table maps each
logical axis to an ordered list of mesh-axis candidates. The first candidate
that (a) exists in the active mesh and (b) evenly divides the dimension is
chosen; otherwise the dimension is replicated. This is what lets one rule
table serve archs whose head counts (24, 40, 8, …) don't all divide the
16-way model axis — see DESIGN.md §4.

Activations use `shard(x, *logical_axes)` (a with_sharding_constraint that
is a no-op outside a mesh context); parameters/caches get PartitionSpecs via
`logical_to_spec`.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis → ordered mesh-axis candidates; a tuple candidate means the
# dim shards over the COMBINED axes (e.g. pod×data = 32-way DP)
DEFAULT_RULES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("batch", (("pod", "data"), "data", "pod")),   # DP over pod×data
    ("fsdp", (("pod", "data"), "data")),           # ZeRO param/opt sharding
    ("seq", ()),                     # replicated by default (SP opt-in)
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("qdim", ("model",)),            # fused head*dh fallback axis
    ("ff", ("model",)),
    ("experts", ("model",)),
    ("vocab", ("model",)),
    ("d_model", ()),
    ("slots", ("model",)),           # long-context cache slot sharding
    ("stack", ()),                   # scanned layer axis — never sharded
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules = dict(DEFAULT_RULES)
        self.overrides = {}


_CTX = _Ctx()


class use_mesh:
    """Context manager installing a mesh + optional rule overrides."""

    def __init__(self, mesh: Mesh, **rule_overrides):
        self.mesh = mesh
        self.rule_overrides = {k: tuple(v) if not isinstance(v, tuple) else v
                               for k, v in rule_overrides.items()}

    def __enter__(self):
        self._saved = (_CTX.mesh, dict(_CTX.rules))
        _CTX.mesh = self.mesh
        _CTX.rules.update(self.rule_overrides)
        return self.mesh

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._saved
        return False


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _pick_axis(logical: str, dim: int, mesh: Mesh, used: set):
    """First viable candidate; tuple candidates shard over combined axes."""
    for cand in _CTX.rules.get(logical, ()):
        axes = cand if isinstance(cand, tuple) else (cand,)
        if any(a not in mesh.shape or a in used for a in axes):
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and dim % size == 0:
            return cand
    return None


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int],
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names (None = replicated) to a PartitionSpec."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    used: set = set()
    out = []
    for name, dim in zip(logical_axes, shape):
        ax = _pick_axis(name, dim, mesh, used) if name else None
        if ax:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh))


# ---------------------------------------------------------------------------
# Parameter / state sharding rules (path-name based, MaxText-style)
# ---------------------------------------------------------------------------

# (substring-of-path, trailing logical axes). First match wins; extra leading
# dims (scanned layer stacks) are padded with None ('stack').
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("embed", ("vocab", "fsdp")),
    ("lm_head", ("fsdp", "vocab")),
    ("frontend_adapter", ("fsdp", None)),
    ("mtp/proj", ("fsdp", None)),
    # attention
    ("attn/wq_a", ("fsdp", None)),
    ("attn/wkv_a", ("fsdp", None)),
    ("attn/wq_b", (None, "qdim")),
    ("attn/wkv_b", (None, "qdim")),
    ("attn/wq", ("fsdp", "qdim")),
    ("attn/wk", ("fsdp", "qdim")),
    ("attn/wv", ("fsdp", "qdim")),
    ("attn/wo", ("qdim", "fsdp")),
    ("xattn/wq", ("fsdp", "qdim")),
    ("xattn/wk", ("fsdp", "qdim")),
    ("xattn/wv", ("fsdp", "qdim")),
    ("xattn/wo", ("qdim", "fsdp")),
    # MoE (3D expert weights) before dense MLP rules
    ("moe/router", (None, None)),
    # experts → model when divisible (EP); otherwise the expert-ffn dim
    # takes the model axis (grok-1: 8 experts < 16-way model axis)
    ("moe/wi", ("experts", "fsdp", "ff")),
    ("moe/wg", ("experts", "fsdp", "ff")),
    ("moe/wo", ("experts", "ff", "fsdp")),
    ("moe/shared", ("fsdp", "ff")),      # overridden below for wo by order
    # dense MLP
    ("mlp/wi", ("fsdp", "ff")),
    ("mlp/wg", ("fsdp", "ff")),
    ("mlp/wo", ("ff", "fsdp")),
    # SSM
    ("ssm/in_proj", ("fsdp", "ff")),
    ("ssm/out_proj", ("ff", "fsdp")),
)


def param_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Trailing-dim logical axes for a parameter leaf path like
    'seg0_moe/3/moe/wi'. Unmatched leaves are replicated."""
    # moe shared-expert wo needs the transposed rule
    if "moe/shared" in path and path.endswith("wo"):
        base: Tuple[Optional[str], ...] = ("ff", "fsdp")
    else:
        base = None
        for pat, axes in _PARAM_RULES:
            if pat in path:
                base = axes
                break
        if base is None:
            return (None,) * ndim
    if ndim < len(base):            # e.g. biases: replicate
        return (None,) * ndim
    return (None,) * (ndim - len(base)) + tuple(base)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):          # DictKey
            parts.append(str(k.key))
        elif hasattr(k, "name"):       # GetAttrKey (NamedTuple fields)
            parts.append(str(k.name))
        elif hasattr(k, "idx"):        # SequenceKey
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def params_pspecs(tree, mesh: Optional[Mesh] = None):
    """Pytree of PartitionSpecs for a parameter/optimizer-state tree.

    Works on arrays or ShapeDtypeStructs. QTensor leaves (int8 codes +
    per-row scale) inherit the parent parameter's rule — the scale's size-1
    trailing dim fails divisibility and is auto-replicated.
    """
    mesh = mesh or _CTX.mesh

    def one(path, leaf):
        p = _path_str(path)
        axes = param_logical_axes(p, leaf.ndim)
        return logical_to_spec(axes, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, tree)


def params_shardings(tree, mesh: Optional[Mesh] = None):
    mesh = mesh or _CTX.mesh
    specs = params_pspecs(tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Decode-state (KV cache / SSM state) sharding
# ---------------------------------------------------------------------------


def cache_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for DecodeState leaves (leading dim = layer stack)."""
    name = path.rsplit("/", 1)[-1]
    table = {
        # KVCache fields: [L, B, Hk, S, d] / [L, B, Hk, S] / [L, B]
        "k": ("stack", "batch", "kv_heads", "slots", None),
        "v": ("stack", "batch", "kv_heads", "slots", None),
        "kq": ("stack", "batch", "kv_heads", "slots", None),
        "kscale": ("stack", "batch", "kv_heads", "slots"),
        "acc": ("stack", "batch", "kv_heads", "slots"),
        "valid": ("stack", "batch", "kv_heads", "slots"),
        "pos": ("stack", "batch", "kv_heads", "slots"),
        "fill": ("stack", "batch"),
        "step": ("stack", "batch"),
        # SSMState: conv [L,B,K-1,C], ssm [L,B,H,P,N]
        "conv": ("stack", "batch", None, "ff"),
        "ssm": ("stack", "batch", "heads", None, None),
    }
    axes = table.get(name)
    if axes is None or len(axes) != ndim:
        return (None,) * ndim
    return axes


def lane_pspecs(tree, mesh: Mesh, *, axis: int = 1):
    """PartitionSpecs laying a stacked DecodeState out ``P("data")`` on
    the LANE axis only — the data-parallel serving layout.

    Every stacked decode-state array carries layers on axis 0 and lanes
    (the serving batch) on axis 1 (`models/transformer.py` lane-surgery
    contract), so each leaf shards axis `axis` over the mesh's ``data``
    axis and replicates everything else. Unlike `decode_state_pspecs`
    there is no fallback folding: lanes must divide the shard count
    (asserted), heads/slots stay whole per shard, and the resulting
    decode block is collective-free — each shard owns a contiguous
    block of lanes end to end (cache, knobs, PRNG keys).
    """
    n = int(mesh.shape["data"])

    def one(leaf):
        assert leaf.ndim > axis and leaf.shape[axis] % n == 0, (
            f"lane axis {axis} of shape {leaf.shape} not divisible by "
            f"{n}-way data mesh")
        cols: list = [None] * leaf.ndim
        cols[axis] = "data"
        while cols and cols[-1] is None:
            cols.pop()
        return P(*cols)

    return jax.tree.map(one, tree)


def lane_shardings(tree, mesh: Mesh, *, axis: int = 1):
    """NamedShardings for `lane_pspecs` — feed straight to device_put."""
    specs = lane_pspecs(tree, mesh, axis=axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def decode_state_pspecs(tree, mesh: Optional[Mesh] = None):
    """PartitionSpecs for a DecodeState pytree.

    kv_heads shards over `model` when divisible; otherwise `slots` takes the
    model axis (flash-decode style — softmax over a sharded slot axis, XLA
    inserts the partial-max/sum collectives). For batch=1 long-context cells
    every idle mesh axis is folded onto `slots`, so a 500k-slot cache spreads
    over all 256/512 chips.
    """
    mesh = mesh or _CTX.mesh

    def one(path, leaf):
        p = _path_str(path)
        axes = list(cache_logical_axes(p, leaf.ndim))
        spec = logical_to_spec(axes, leaf.shape, mesh)
        cols = list(spec) + [None] * (leaf.ndim - len(spec))
        if mesh is not None and "slots" in axes:
            i_s = axes.index("slots")
            used = {c for c in cols if isinstance(c, str)}
            for c in cols:
                if isinstance(c, tuple):
                    used.update(c)
            combo = [cols[i_s]] if cols[i_s] else []
            for ax in ("model", "data", "pod"):
                if ax in used or ax not in mesh.shape:
                    continue
                factor = 1
                for a in combo:
                    factor *= mesh.shape[a]
                if leaf.shape[i_s] % (factor * mesh.shape[ax]) == 0:
                    combo.append(ax)
                    used.add(ax)
            cols[i_s] = tuple(combo) if len(combo) > 1 else (
                combo[0] if combo else None)
        while cols and cols[-1] is None:
            cols.pop()
        return P(*cols)

    return jax.tree_util.tree_map_with_path(one, tree)
