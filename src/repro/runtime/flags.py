"""Global lowering flags.

`unroll_scans()` makes every framework scan (layer stacks, attention chunk
loops, SSD chunk recurrences) fully unroll. Used by the dry-run cost probes:
XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
so roofline numbers must come from unrolled HLO. Full-depth compiles stay
scanned (compile-time proof + memory analysis); shallow unrolled probes
recover exact per-layer costs by linear extrapolation (launch/dryrun.py).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_UNROLL = [False]


@contextmanager
def unroll_scans(on: bool = True):
    prev = _UNROLL[0]
    _UNROLL[0] = on
    try:
        yield
    finally:
        _UNROLL[0] = prev


def scans_unrolled() -> bool:
    return _UNROLL[0]


def xscan(body, init, xs, length=None):
    """jax.lax.scan honouring the global unroll flag."""
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if _UNROLL[0] else 1)
