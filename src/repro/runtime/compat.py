"""Version compatibility shims for the jax API surface we depend on.

`shard_map` moved from `jax.experimental.shard_map` to the `jax` top level,
and its replication-check kwarg was renamed `check_rep` → `check_vma` along
the way. Every call site in this repo imports the wrapper below, which
accepts `check_vma` and translates to whatever the installed jax expects.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.6: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map
    _CHECK_KWARG = "check_vma"
except ImportError:  # older jax: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


@functools.wraps(_shard_map)
def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    if check_vma is not None:
        kwargs[_CHECK_KWARG] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
