"""Deterministic fault injection for the SERVING path.

`runtime/fault.py` drives the training loop's inject-and-recover story;
this module is its serving twin: a frozen `ChaosConfig` that injects

  * **logit corruption** — a per-(block, step, lane) NaN mask, derived
    from `(seed, block_index)` alone, that the decode block applies
    in-device (`decode_block_lanes(fault=...)`). The poisoned lane trips
    the non-finite sentinel and exercises quarantine + retry;
  * **dispatch stalls** — a host-side sleep before chosen decode blocks
    (a slow interconnect / preempted host slice), exercising deadline
    expiry without touching any numerics;
  * **queue floods** — a burst of synthetic `Request` kwargs, exercising
    bounded admission (`max_queue`) and the degradation ladder;
  * **shard blackouts** — a scheduler-round interval during which one
    shard's free lanes are hidden from admission (a brownout: resident
    lanes keep decoding, no NEW work lands on the shard).

Everything is a pure function of (seed, block index / round index), so
every recovery path is replayable bit-for-bit: the same config injects
the same faults into the same dispatch sequence, and the engine's
recovered token streams can be asserted token-identical to a clean run
(`tests/test_chaos_serve.py`, the `chaos-smoke` CI job).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One deterministic fault-injection plan.

    `logit_fault_rate` is the per-(step, lane) corruption probability
    inside each targeted decode block; `fault_blocks`/`fault_lanes`
    restrict which block indices / lanes can be hit (None = all).
    `stall_blocks` sleep `stall_s` seconds before those decode blocks.
    `blackout_shard` hides that shard's free lanes from admission for
    scheduler rounds in `[blackout_rounds[0], blackout_rounds[1])`.
    """
    seed: int = 0
    logit_fault_rate: float = 0.0
    fault_blocks: Optional[Tuple[int, ...]] = None
    fault_lanes: Optional[Tuple[int, ...]] = None
    stall_blocks: Tuple[int, ...] = ()
    stall_s: float = 0.0
    blackout_shard: int = -1
    blackout_rounds: Tuple[int, int] = (0, 0)

    def fault_mask(self, block: int, steps: int, lanes: int) -> np.ndarray:
        """[steps, lanes] bool: which decode positions of block `block`
        get their logits poisoned. Derived from (seed, block) alone —
        independent of call order, so a replayed run injects identically."""
        mask = np.zeros((steps, lanes), bool)
        if self.logit_fault_rate <= 0.0:
            return mask
        if self.fault_blocks is not None and block not in self.fault_blocks:
            return mask
        rng = np.random.default_rng([self.seed, block])
        mask = rng.random((steps, lanes)) < self.logit_fault_rate
        if self.fault_lanes is not None:
            keep = np.zeros(lanes, bool)
            keep[list(self.fault_lanes)] = True
            mask &= keep[None, :]
        return mask

    def stall(self, block: int) -> float:
        """Seconds to sleep before decode block `block` (0 = none)."""
        return self.stall_s if block in self.stall_blocks else 0.0

    def blacked_out(self, round_: int, shard: int) -> bool:
        """Whether `shard` is admission-blacked-out at scheduler round
        `round_` (rounds advance once per `run()` iteration, so a
        blackout always expires even when nothing else makes progress)."""
        lo, hi = self.blackout_rounds
        return shard == self.blackout_shard and lo <= round_ < hi

    @property
    def any_faults(self) -> bool:
        """Whether this config can inject anything at all (an inert
        config lets the engine skip per-block mask construction)."""
        return (self.logit_fault_rate > 0.0 or bool(self.stall_blocks)
                or self.blackout_shard >= 0)


def flood(vocab: int, n: int, length: int = 16, max_new: int = 8,
          priority: int = 0, seed: int = 0, arrival: float = 0.0):
    """`n` synthetic same-shape request kwargs for a queue-flood burst —
    deterministic in `seed`, ready for `Request(**kw)`."""
    rng = np.random.default_rng(seed)
    return [dict(prompt=rng.integers(0, vocab, length), max_new=max_new,
                 priority=priority, arrival=arrival) for _ in range(n)]
