"""Fault tolerance: checkpoint/restart step loop, straggler watchdog,
elastic re-mesh.

On a real fleet the failure signals come from the coordination service
(missing heartbeats, preempted VMs); in this single-host environment the
same control flow is driven by raised exceptions and injected faults (the
tests use `inject_failure`). What matters for the 1000+-node story:

  * the train step is a pure function of (state, batch) — restart-safe;
  * data is resumable from the step index alone (deterministic pipeline);
  * checkpoints commit atomically (rename), so a crash mid-save is harmless;
  * restore accepts a DIFFERENT mesh than the one that saved (elastic):
    shardings are recomputed from logical rules for the new topology;
  * a per-step deadline flags stragglers; the hook can re-shard or skip.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager

log = logging.getLogger("repro.fault")


@dataclass
class FaultConfig:
    ckpt_every: int = 50
    max_restarts: int = 3
    step_deadline_s: float = 0.0      # 0 = disabled
    straggler_action: str = "log"     # 'log' | 'raise'


@dataclass
class LoopStats:
    steps: int = 0
    restarts: int = 0
    straggler_events: int = 0
    losses: list = field(default_factory=list)


def run_training(step_fn: Callable, state: Any, data_iter, num_steps: int,
                 ckpt: CheckpointManager, fcfg: FaultConfig,
                 start_step: int = 0,
                 inject_failure: Optional[Callable[[int], None]] = None,
                 on_metrics: Optional[Callable] = None) -> tuple:
    """Fault-tolerant training loop.

    step_fn: (state, batch) → (state, metrics). Must be jitted & pure.
    Returns (state, LoopStats). Restores from the latest checkpoint and
    replays data on failure (the pipeline is deterministic in step index).
    """
    stats = LoopStats()
    step = start_step
    restarts = 0
    state_template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)

    while step < num_steps:
        try:
            batch = data_iter(step)
            t0 = time.monotonic()
            if inject_failure is not None:
                inject_failure(step)
            state, metrics = step_fn(state, batch)
            if on_metrics is not None:
                jax.block_until_ready(metrics)
                on_metrics(step, metrics)
                if isinstance(metrics, dict) and "loss" in metrics:
                    stats.losses.append(float(metrics["loss"]))
            dt = time.monotonic() - t0
            if fcfg.step_deadline_s and dt > fcfg.step_deadline_s:
                stats.straggler_events += 1
                log.warning("straggler: step %d took %.3fs (deadline %.3fs)",
                            step, dt, fcfg.step_deadline_s)
                if fcfg.straggler_action == "raise":
                    raise TimeoutError(f"step {step} exceeded deadline")
            step += 1
            stats.steps += 1
            if step % fcfg.ckpt_every == 0:
                ckpt.save(step, state)
        except (TimeoutError, RuntimeError, ValueError) as e:
            restarts += 1
            stats.restarts = restarts
            if restarts > fcfg.max_restarts:
                raise RuntimeError(
                    f"exceeded {fcfg.max_restarts} restarts") from e
            log.warning("step %d failed (%s); restoring latest checkpoint",
                        step, e)
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is not None:
                state = ckpt.restore(latest, state_template)
                step = latest
            else:
                step = start_step
    ckpt.save(step, state, block=True)
    return state, stats


def elastic_restore(ckpt: CheckpointManager, state_template: Any,
                    make_shardings: Callable[[], Any]) -> Any:
    """Restore the latest checkpoint onto the CURRENT mesh topology.

    `make_shardings()` recomputes NamedShardings from logical rules under
    the active mesh — the same checkpoint restores onto 256 or 512 chips.
    """
    shardings = make_shardings()
    return ckpt.restore_latest(state_template, shardings)
