"""Shard-aware token data pipeline.

Two sources behind one iterator interface:
  * SyntheticSource — deterministic pseudo-text (Zipfian tokens with local
    n-gram structure so small models have signal to learn); reproducible
    per (seed, step), so restarts resume bit-identically without data state.
  * MemmapSource — packed uint16/uint32 token files (the production path).

`DataPipeline` slices the global batch for this process, device_puts with
the active mesh's batch sharding, and prefetches one batch ahead on a
background thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.sharding import named_sharding


class SyntheticSource:
    """Deterministic synthetic LM data with learnable structure."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.seed = seed

    def batch(self, step: int, batch_size: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        # zipfian unigrams
        base = rng.zipf(1.3, size=(batch_size, self.seq)).astype(np.int64)
        toks = (base - 1) % self.vocab
        # inject learnable bigram structure: token 2k+1 follows 2k
        follow = (toks + 1) % self.vocab
        mask = rng.random((batch_size, self.seq)) < 0.5
        shifted = np.roll(follow, 1, axis=1)
        toks = np.where(mask, shifted, toks)
        return toks.astype(np.int32)


class MemmapSource:
    """Packed token file: flat array of token ids."""

    def __init__(self, path: str, seq_len: int, dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.n_windows = len(self.data) // seq_len

    def batch(self, step: int, batch_size: int) -> np.ndarray:
        rng = np.random.default_rng(step)
        idx = rng.integers(0, self.n_windows, size=batch_size)
        out = np.stack([self.data[i * self.seq:(i + 1) * self.seq]
                        for i in idx])
        return out.astype(np.int32)


class DataPipeline:
    def __init__(self, source, global_batch: int, start_step: int = 0,
                 prefetch: int = 2, process_index: int = 0,
                 process_count: int = 1, extras: Optional[dict] = None):
        assert global_batch % process_count == 0
        self.source = source
        self.global_batch = global_batch
        self.local_batch = global_batch // process_count
        self.process_index = process_index
        self.step = start_step
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> dict:
        toks = self.source.batch(step, self.global_batch)
        lo = self.process_index * self.local_batch
        batch = {"tokens": toks[lo:lo + self.local_batch]}
        for name, fn in self.extras.items():
            batch[name] = fn(step, self.local_batch)
        return batch

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = self._q.get()
        self.step += 1
        return self._device_put(batch)

    def _device_put(self, batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            sh = named_sharding(("batch",) + (None,) * (v.ndim - 1), v.shape)
            out[k] = jax.device_put(v, sh) if sh is not None else jnp.asarray(v)
        return out

    def close(self):
        self._stop.set()
