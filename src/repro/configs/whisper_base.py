"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865. Encoder-decoder with conv frontend STUB (input_specs provides
precomputed mel-frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,           # per-stack depth (enc_layers/dec_layers govern)
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    max_seq_len=32768,      # stress config per assignment (real model: 448)
    norm="ln",
    act="gelu",
    pos="sinusoidal",
    qkv_bias=True,
    frontend="audio",
    frontend_len=1500,      # encoder positions (precomputed frame embeddings)
))
