"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + SHARED attention block applied every
`attn_period` blocks (weights reused, Zamba2-style). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    norm="rms",
    act="swiglu",
    pos="rope",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    attn_period=6,
))
