"""Configuration system for the UniCAIM reproduction framework.

Three config families:
  * ModelConfig  — architecture hyper-parameters (one instance per assigned arch)
  * PruneConfig  — the paper's static-dynamic KV-cache pruning knobs
  * ShapeConfig  — assigned (seq_len, global_batch, kind) input shapes

Configs are frozen dataclasses so they hash (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Pruning (the paper's technique)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PruneConfig:
    """UniCAIM static-dynamic KV cache pruning configuration.

    policy:
      'unicaim'   — paper technique: quantized approx scoring (CAM mode),
                    top-k dynamic selection, accumulated-score static eviction
      'h2o'       — exact-score accumulation + static eviction, no dynamic top-k
      'streaming' — StreamingLLM: sinks + sliding window (position eviction)
      'dense'     — no pruning (baseline)
    """

    policy: str = "unicaim"
    # --- static budget: S = heavy_budget + reserve slots (paper: 512 + 64) ---
    heavy_budget: int = 512
    reserve: int = 64
    # --- protected tokens (never evicted, always selected) ---
    sink_tokens: int = 4
    recent_window: int = 32
    # --- CAM mode: approximate scoring precision (paper: 1..3 bit cells) ---
    score_bits: int = 3          # key mirror bits (1..8); 8 == int8
    query_bits: int = 4          # query "bitwise expansion" bits
    # --- dynamic selection ---
    select_k: int = 64           # top-k tokens entering exact attention
    select_mode: str = "topk"    # 'topk' (lax.top_k) | 'threshold' (CAM race)
    threshold_iters: int = 8     # binary-search iterations for the CAM race
    # >1: hierarchical selection — top-(k/nb) within each of nb slot blocks.
    # With slots sharded over `model`, blocks align with shards, so select +
    # gather + exact attention stay SHARD-LOCAL (the distributed analog of
    # the paper's per-array CAM race). §Perf optimization for decode cells.
    select_blocks: int = 1
    # --- cache storage precision (paper: the SAME multilevel FeFET cells
    #     store the cache — low-bit storage is the faithful reading).
    #     'int8': K/V stored int8 + per-(token,head) scales; the int8 K IS
    #     the scoring mirror (no separate copy). Halves cache bytes AND the
    #     CAM-pass reads. §Perf/memory knob for long-context decode. ---
    kv_dtype: str = "bf16"       # 'bf16' | 'int8' (unicaim policy only)
    # --- fused single-pass decode engine (kernels/fused_decode.py):
    #     scoring, block-local selection, winner gather, and exact
    #     attention in one kernel/XLA region instead of the composed
    #     three-pass flow. The composed path stays as the oracle.
    #     fused="auto" picks the measured-faster engine per backend: the
    #     Pallas kernel on TPU (where its winner-only DMA gather pays),
    #     the composed path elsewhere (the XLA fallback was measured at
    #     parity-to-slower off-TPU — see core/attention.fused_auto_decision,
    #     which benches record into BENCH_latency.json). ---
    fused: object = False        # False | True | "auto"
    fused_backend: str = "auto"  # 'auto' | 'pallas' | 'xla'
    # --- charge-domain accumulation ---
    accumulate: str = "approx"   # 'approx' (same-cycle, paper) | 'exact'
    acc_decay: float = 1.0       # optional exponential decay of history
    init_new_score: str = "mean"  # 'mean' | 'zero' — acc init for new tokens
    # --- prefill scoring: 0 = accumulate over all queries (H2O-style);
    #     >0 = only the last W queries (SnapKV-style observation window) ---
    prefill_obs_window: int = 0

    @property
    def slots(self) -> int:
        return self.heavy_budget + self.reserve

    def validate(self) -> None:
        assert self.policy in ("unicaim", "h2o", "streaming", "dense")
        assert 1 <= self.score_bits <= 8
        assert 1 <= self.query_bits <= 8
        assert self.select_mode in ("topk", "threshold")
        assert self.fused in (True, False, "auto")
        assert self.fused_backend in ("auto", "pallas", "xla")
        assert self.accumulate in ("approx", "exact")
        assert self.select_k <= self.slots
        assert self.sink_tokens + self.recent_window < self.slots


# ---------------------------------------------------------------------------
# Model architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0            # shared (always-on) experts
    d_ff_expert: int = 2048      # per-expert hidden dim
    dense_first_k: int = 0       # first K layers use dense FFN (deepseek-v3)
    d_ff_dense: int = 0          # hidden dim of those dense layers
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128

    @property
    def latent_dim(self) -> int:       # cached per-token latent width
        return self.kv_lora_rank + self.qk_rope_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256
    n_groups: int = 1            # B/C projection groups


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | mla_moe | ssm | hybrid | encdec
    num_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 32768
    # layer flavour
    norm: str = "rms"            # rms | ln
    act: str = "swiglu"          # swiglu | gelu | relu2
    pos: str = "rope"            # rope | sinusoidal | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    qkv_bias: bool = False
    # families
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): shared attention block every `attn_period` ssm blocks
    attn_period: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    # multimodal frontend stub: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    frontend_len: int = 0        # number of frontend embedding positions
    # multi-token prediction depth (deepseek-v3 MTP); 0 = off
    mtp_depth: int = 0
    # chunk length for the XLA chunked-attention scan (train/prefill);
    # larger chunks re-read full K/V fewer times (§Perf memory knob)
    attn_chunk: int = 512
    # expert-parallel MoE dispatch via shard_map all_to_all instead of the
    # XLA-propagated sort-based dispatch (§Perf collective knob)
    moe_ep: bool = False
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (total; MoE counts all experts)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            n_heads = d_in // s.head_dim
            per = (d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
                   + s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)
                   + d_in * d + 2 * n_heads + d)                          # out_proj+A,D+norm
            return emb + L * per
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_dim)
                    + self.n_heads * m.v_dim * d)
        ff_mult = 3 if self.act == "swiglu" else 2
        if self.moe is not None:
            mo = self.moe
            moe_layers = L - mo.dense_first_k
            per_expert = ff_mult * d * mo.d_ff_expert
            ff = (moe_layers * (mo.n_experts + mo.n_shared) * per_expert
                  + moe_layers * d * mo.n_experts                     # router
                  + mo.dense_first_k * ff_mult * d * mo.d_ff_dense)
            return emb + L * (attn + 2 * d) + ff
        ff = L * ff_mult * d * self.d_ff
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            n_ssm_heads = d_in // s.head_dim
            ssm_per = (d * (2 * d_in + 2 * s.n_groups * s.d_state + n_ssm_heads)
                       + s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)
                       + d_in * d + 2 * n_ssm_heads + d)
            shared = attn + ff_mult * d * self.d_ff + 2 * d
            return emb + L * ssm_per + shared
        if self.family == "encdec":
            # enc: self-attn + ff; dec: self + cross + ff
            per_enc = attn + ff_mult * d * self.d_ff + 2 * d
            per_dec = 2 * attn + ff_mult * d * self.d_ff + 3 * d
            return emb + self.enc_layers * per_enc + self.dec_layers * per_dec
        return emb + L * (attn + 2 * d) + ff

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.num_layers
        ff_mult = 3 if self.act == "swiglu" else 2
        total = self.param_count()
        moe_layers = L - mo.dense_first_k
        per_expert = ff_mult * d * mo.d_ff_expert
        all_experts = moe_layers * mo.n_experts * per_expert
        active_experts = moe_layers * mo.top_k * per_expert
        return total - all_experts + active_experts


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                    # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int

    def validate(self) -> None:
        assert self.kind in ("train", "prefill", "decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import all config modules once so they register themselves
    if _REGISTRY.get("__loaded__"):
        return
    from repro.configs import (  # noqa: F401
        whisper_base, minitron_8b, starcoder2_3b, phi3_medium_14b,
        granite_3_2b, deepseek_v3_671b, grok1_314b, zamba2_7b,
        mamba2_1p3b, llava_next_mistral_7b, longchat_7b,
    )
    _REGISTRY["__loaded__"] = True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build a tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab_size=256, max_seq_len=512,
        param_dtype="float32", compute_dtype="float32",
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
            dense_first_k=min(cfg.moe.dense_first_k, 1), d_ff_dense=128)
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                 qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
        small["head_dim"] = 16
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                           chunk_size=32)
    if cfg.family == "hybrid":
        small["num_layers"] = 4
        small["attn_period"] = 2
    if cfg.family == "encdec":
        small["enc_layers"] = 2
        small["dec_layers"] = 2
    if cfg.frontend != "none":
        small["frontend_len"] = 8
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
