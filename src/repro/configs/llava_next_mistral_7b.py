"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — mistral backbone; anyres vision tiling is a STUB
(input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    norm="rms",
    act="swiglu",
    pos="rope",
    frontend="vision",
    frontend_len=576,       # one 24x24 patch grid (anyres tiles stubbed)
))
