"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2. [hf:xai-org/grok-1]"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    norm="rms",
    act="swiglu",    # gated expert FFN (3 matrices) — matches the 314B count
    pos="rope",
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        n_shared=0,
        d_ff_expert=32768,
    ),
))
