"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

UniCAIM KV pruning is INAPPLICABLE (no KV cache exists); see DESIGN.md
§Arch-applicability. Included for native sub-quadratic long_500k decode."""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    norm="rms",
    act="swiglu",
    pos="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
))
