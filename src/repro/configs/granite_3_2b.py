"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    norm="rms",
    act="swiglu",
    pos="rope",
    tie_embeddings=True,
))
