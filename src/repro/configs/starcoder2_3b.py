"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    norm="ln",
    act="gelu",
    pos="rope",
    rope_theta=999999.0,
    qkv_bias=True,
))
