"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MoE 256e top-8, 1 shared expert, MLA latent attention,
first 3 layers dense FFN (d_ff 18432), optional MTP head. [arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    num_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,           # qk_nope/v head dim (MLA governs actual dims)
    d_ff=18432,             # dense layers' hidden
    vocab_size=129280,
    norm="rms",
    act="swiglu",
    pos="rope",
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        dense_first_k=3,
        d_ff_dense=18432,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_dim=128,
    ),
    mtp_depth=1,
))
