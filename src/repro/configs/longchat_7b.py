"""longchat-v1.5-7b-32k — the paper's own evaluation model (llama-2-7b
derivative with 32k context): 32L d_model=4096 32H MHA d_ff=11008
vocab=32000. Used for application-level benchmarks (paper Fig. 13)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="longchat-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    norm="rms",
    act="swiglu",
    pos="rope",
))
