"""Gradient compression for data-parallel all-reduce (beyond-paper
distributed-optimization trick, DESIGN.md §4).

bf16 gradients are quantized to int8 with per-row scales before the DP
reduction and dequantized after, cutting all-reduce bytes ~2× vs bf16
(~4× vs f32). An error-feedback buffer re-injects the quantization residual
into the next step so convergence is unaffected (Karimireddy et al. 2019).

`compressed_psum` runs the reduction inside shard_map so the HLO all-reduce
really carries int8 (+ f32 row scales) — visible in the dry-run collective
bytes. The scale factors are reduced separately; each shard's contribution
is dequantized with its own scale (sum of per-shard dequant == exact sum of
quantized shards).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from repro.runtime.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


class ErrorFeedback(NamedTuple):
    residual: Any          # pytree like grads (f32)


def init_error_feedback(grads_shape) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape))


def quantize_grad(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-20)
    return jnp.round(gf / scale).astype(jnp.int8), scale


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, ef: ErrorFeedback):
    """Quantize (grads + residual); returns (q, scales, new_feedback)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_l = treedef.flatten_up_to(ef.residual)
    qs, scales, res = [], [], []
    for g, r in zip(leaves, res_l):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_grad(gf)
        qs.append(q)
        scales.append(s)
        res.append(gf - dequantize_grad(q, s))
    return (treedef.unflatten(qs), treedef.unflatten(scales)), \
        ErrorFeedback(residual=treedef.unflatten(res))


def compressed_psum(x: jax.Array, mesh: Mesh, axis: str = "data"
                    ) -> jax.Array:
    """All-reduce-mean of x over `axis` with int8 payload.

    x must be identically shaped on every shard (replicated layout); the
    shard_map keeps it unsharded on other axes.
    """
    n = mesh.shape[axis]

    # A true multi-scale int8 ring all-reduce needs per-hop requantization;
    # we implement the standard "quantize → all-gather int8 → local sum"
    # that gradient-compression systems (e.g. 1-bit Adam) ship. The wire
    # payload is int8 codes + per-row f32 scales (~2× fewer bytes than bf16).
    def gather_body(xl):
        q, s = quantize_grad(xl)
        qg = jax.lax.all_gather(q, axis)                     # [n, ...] int8
        sg = jax.lax.all_gather(s, axis)                     # [n, ...] f32
        return jnp.sum(qg.astype(jnp.float32) * sg, axis=0) / n

    spec = P()  # replicated in/out
    fn = shard_map(gather_body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_vma=False)
    return fn(x)
