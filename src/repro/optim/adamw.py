"""AdamW with optional int8-quantized moments (8-bit-Adam style).

Quantized states use per-row (last-axis-block) scales so the memory cost is
~2.06 bytes/param for (m, v) instead of 8 — the trick that lets
deepseek-v3-671b training state fit the v5e HBM budget (DESIGN.md §4).
State layout mirrors params, so FSDP sharding rules apply unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array        # int8 codes
    scale: jax.Array    # f32 per-row scale


def _q8(x: jax.Array) -> QTensor:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    return QTensor(jnp.round(xf / scale).astype(jnp.int8), scale)


def _dq8(t: QTensor) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any              # pytree of f32 or QTensor
    v: Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False


def init(params, cfg: AdamWConfig) -> AdamWState:
    def zero(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _q8(z) if cfg.quantized_state and p.ndim >= 1 else z
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zero, params),
                      v=jax.tree.map(zero, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads, state: AdamWState, params, cfg: AdamWConfig,
           lr: Optional[jax.Array] = None) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state)."""
    lr = cfg.lr if lr is None else lr
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        mf = _dq8(m) if isinstance(m, QTensor) else m
        vf = _dq8(v) if isinstance(v, QTensor) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        pn = (p.astype(jnp.float32)
              - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32)))
        m_out = _q8(mf) if isinstance(m, QTensor) else mf
        v_out = _q8(vf) if isinstance(v, QTensor) else vf
        return pn.astype(p.dtype), m_out, v_out

    # tree_map flattens (grads, m, v) against params' treedef, so QTensor
    # subtrees arrive at `upd` intact.
    leaves, treedef = jax.tree.flatten(params)
    g_l = treedef.flatten_up_to(grads)
    m_l = treedef.flatten_up_to(state.m)
    v_l = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(leaves, g_l, m_l, v_l)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
