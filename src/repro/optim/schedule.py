"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * (s + 1.0) / max(warmup, 1)   # step 0 is never a no-op
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)


def constant(step, lr: float):
    return jnp.full_like(step, lr, dtype=jnp.float32)
