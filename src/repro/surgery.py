"""Cache & decode-state surgery — the single public namespace.

Lane-granular continuous batching grew its splice primitives across two
modules: per-field `KVCache` surgery in `repro.core.cache` and the
DecodeState-level wrappers (KV + SSM recurrent state + enc-dec cross
K/V) in `repro.models.transformer`. This module is the one documented
place to import them from; the serving engine's admission and
prefix-copy paths (`launch/serve.py`, `launch/prefix_cache.py`) resolve
every splice through these names.

Naming convention — the prefix says what a helper operates on:

``state_*`` — whole `DecodeState` pytrees (batch axis 1, layer-stacked):
  state_lane_slice(state, lane)            one lane as a batch-1 state
  state_lane_insert(state, lane, fresh)    splice a batch-1 state in
  state_lanes_insert(state, src, fresh)    multi-lane scatter splice
  state_lane_select(active, new, old)      per-lane merge (termination)

The slice/insert pair is also the scheduler's preemption machinery: a
priority eviction captures the victim lane with `state_lane_slice`
(jit-compiled, traced lane index) and the later resume splices the
snapshot back with `state_lane_insert` — mid-stream, token-identically
(`launch/serve.py::ServeLoop._preempt_lane` / `_admit_resumed`).

``kv_*`` — bare `KVCache` instances (batch_axis selects layout):
  kv_lane_slice / kv_lane_insert / kv_lanes_insert / kv_lane_reset

Slot-axis windows (fill-aware decode cost):
  slot_window(cache, w)                    first-w-slots view
  slot_window_merge(full, win)             write the window back
  decode_window(max_fill, steps, slots, prune)   pow2 window choice

Prefix snapshots (prefix-sharing admission):
  prefix_slot_aligned(kv, length)          identity-layout check
  cache_prefix_rows(kv, length)            host rows [0, length) or None

Both snapshot helpers also serve the preemption path: a victim lane
captured before any decode step advanced it passes the identity-layout
gate and donates its prefix rows to the radix trie instead of idling on
the requeued Request (`ServeLoop._cache_insert_preempted`, counted by
``counters["preempt_cache_inserts"]``).

Lane-axis sharding layout (data-sharded serving):
  lane_pspecs(tree, mesh, axis=1)          P("data") specs on the lane axis
  lane_shardings(tree, mesh, axis=1)       ... as NamedShardings

These place a stacked `DecodeState` (every leaf is [layers, lanes, ...])
on a 1-D `"data"` mesh so `ServeLoop(mesh=...)`'s shard_map decode block
runs collective-free; the splice helpers above stay host-side and
shard-agnostic (device_put re-pins after surgery).

All splices copy every cache field — including the int8/quantized
mirrors, their scales, and the accumulated eviction scores — so
per-lane pruning state stays exact across surgery; see the docstrings
on the underlying functions for the per-field contracts.
"""
from __future__ import annotations

from repro.core.cache import (cache_prefix_rows, decode_window,
                              lane_insert as kv_lane_insert,
                              lane_reset as kv_lane_reset,
                              lane_slice as kv_lane_slice,
                              lanes_insert as kv_lanes_insert,
                              prefix_slot_aligned, slot_window,
                              slot_window_merge)
from repro.models.transformer import (lane_insert as state_lane_insert,
                                      lane_select as state_lane_select,
                                      lane_slice as state_lane_slice,
                                      lanes_insert as state_lanes_insert)
from repro.runtime.sharding import lane_pspecs, lane_shardings

__all__ = [
    "state_lane_slice", "state_lane_insert", "state_lanes_insert",
    "state_lane_select",
    "kv_lane_slice", "kv_lane_insert", "kv_lanes_insert", "kv_lane_reset",
    "slot_window", "slot_window_merge", "decode_window",
    "prefix_slot_aligned", "cache_prefix_rows",
    "lane_pspecs", "lane_shardings",
]
