"""GQA attention block wired to the UniCAIM cache (train/prefill/decode)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PruneConfig
from repro.core.attention import (chunked_causal_attention, decode_attention,
                                  decode_attention_stacked,
                                  prefill_chunk_attend)
from repro.core.cache import KVCache
from repro.core.pruning import prefill_and_prune
from repro.models.layers import dense_init, rope
from repro.runtime.sharding import shard


def init_attention(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(k4, cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    """x: [B,T,d] → q [B,Hq,T,dh], k/v [B,Hk,T,dh] (RoPE applied)."""
    b, t, _ = x.shape
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q.transpose(0, 2, 1, 3), "batch", "heads", "seq", None)
    k = shard(k.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", None)
    v = shard(v.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", None)
    return q, k, v


def attention_train(p, x, cfg: ModelConfig, positions,
                    causal: bool = True, chunk: int = 0):
    """Full-sequence attention (training / encoder). x: [B,T,d]."""
    b, t, _ = x.shape
    chunk = chunk or cfg.attn_chunk
    q, k, v = _project_qkv(p, x, cfg, positions)
    if causal:
        out, _ = chunked_causal_attention(q, k, v, chunk=min(chunk, t))
    else:  # encoder: dense bidirectional
        g = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, cfg.n_kv_heads, g, t, cfg.head_dim)
        logits = jnp.einsum("bhgtd,bhsd->bhgts", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) / jnp.sqrt(float(cfg.head_dim))
        pr = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgts,bhsd->bhgtd", pr, v.astype(jnp.float32))
        out = out.reshape(b, cfg.n_heads, t, cfg.head_dim)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"]


def attention_prefill(p, x, cfg: ModelConfig, positions, prune: PruneConfig,
                      cache: KVCache, chunk: int = 0, length=None
                      ) -> Tuple[jax.Array, KVCache]:
    """Prompt pass: dense causal attention + one-shot static pruning.

    `length` ([B] int32, optional): true per-lane lengths for bucketed
    (right-padded) prompts — threaded through to the masked attention and
    the static pruning."""
    b, t, _ = x.shape
    chunk = chunk or cfg.attn_chunk
    q, k, v = _project_qkv(p, x, cfg, positions)
    cache, out = prefill_and_prune(cache, q, k, v, prune,
                                   chunk=min(chunk, t), length=length)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"], cache


def attention_prefill_chunk(p, x, cfg: ModelConfig, positions,
                            prune: PruneConfig, k_buf: jax.Array,
                            v_buf: jax.Array, acc: jax.Array, row0,
                            length):
    """One chunk of a time-sliced (Sarathi-style chunked) prefill.

    x: [B,C,d] hidden for absolute rows [row0, row0+C); k_buf/v_buf:
    [B,Hk,N,dh] streamed prompt K/V (rows < row0 already written); acc:
    [B,Hk,N] running accumulated column sums. Projects the chunk's Q/K/V,
    appends K/V into the buffers at row0, and attends causally over the
    buffer — bit-identical to the same rows of the one-shot
    `attention_prefill` over the full bucket. Returns
    (y [B,C,d], k_buf, v_buf, acc)."""
    b, c, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    k_buf = jax.lax.dynamic_update_slice_in_dim(
        k_buf, k.astype(k_buf.dtype), row0, axis=2)
    v_buf = jax.lax.dynamic_update_slice_in_dim(
        v_buf, v.astype(v_buf.dtype), row0, axis=2)
    out, col = prefill_chunk_attend(q, k_buf, v_buf, row0, length,
                                    obs_window=prune.prefill_obs_window)
    out = out.transpose(0, 2, 1, 3).reshape(b, c, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"], k_buf, v_buf, acc + col


def attention_decode(p, x, cfg: ModelConfig, cache: KVCache,
                     prune: PruneConfig) -> Tuple[jax.Array, KVCache]:
    """One decode step. x: [B,d] → (y [B,d], cache)."""
    b, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    if cfg.pos == "rope":
        pos = cache.step                                    # [B]
        q = rope(q, pos[:, None], cfg.rope_theta)           # [B,H,dh]
        k = rope(k, pos[:, None], cfg.rope_theta)
    cache, out = decode_attention(cache, q, k, v, prune)
    y = out.reshape(b, cfg.q_dim).astype(x.dtype) @ p["wo"]
    return y, cache


def attention_decode_stacked(p, x, cfg: ModelConfig, kv: KVCache, li,
                             prune: PruneConfig, window, active
                             ) -> Tuple[jax.Array, KVCache]:
    """In-place decode step at layer `li` of a layer-stacked cache.

    Same projections + RoPE as `attention_decode` (the per-lane rotation
    anchors on this layer's `step` row, read out of the stacked cache),
    but the cache update goes through `decode_attention_stacked`: window
    reads, scatter writes, stacked buffers aliased end-to-end. x: [B,d]
    → (y [B,d], updated stacked cache)."""
    b, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    if cfg.pos == "rope":
        pos = jax.lax.dynamic_index_in_dim(kv.step, jnp.asarray(li, jnp.int32),
                                           0, keepdims=False)       # [B]
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    kv, out = decode_attention_stacked(kv, li, q, k, v, prune, window,
                                       active)
    y = out.reshape(b, cfg.q_dim).astype(x.dtype) @ p["wo"]
    return y, kv


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: ModelConfig, dtype):
    return init_attention(key, cfg, dtype)


def cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """x: [B,T,d] (or [B,1,d] decode); enc_kv: (k,v) [B,Hk,S,dh]."""
    b, t, _ = x.shape
    k, v = enc_kv
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, t, cfg.n_heads,
                                               cfg.head_dim)
    q = q.transpose(0, 2, 1, 3)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, t, cfg.head_dim)
    logits = jnp.einsum("bhgtd,bhsd->bhgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(cfg.head_dim))
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", pr, v.astype(jnp.float32))
    out = out.reshape(b, cfg.n_heads, t, cfg.head_dim)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"]


def encode_cross_kv(p, enc_out, cfg: ModelConfig):
    """Precompute encoder K/V for the decoder's cross-attention."""
    b, s, _ = enc_out.shape
    k = (enc_out @ p["wk"] + p.get("bk", 0)).reshape(b, s, cfg.n_kv_heads,
                                                     cfg.head_dim)
    v = (enc_out @ p["wv"] + p.get("bv", 0)).reshape(b, s, cfg.n_kv_heads,
                                                     cfg.head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
