"""Mamba2 — State Space Duality (SSD) block. [arXiv:2405.21060]

Train/prefill: chunked SSD (quadratic attention-like within a chunk,
linear recurrence across chunks). Decode: O(1) per-step recurrence on the
[B, H, P, N] state — the sub-quadratic long-context path for the ssm/hybrid
assigned archs. UniCAIM pruning is inapplicable here (no KV cache); see
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import xscan

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm_gated
from repro.runtime.sharding import shard


class SSMState(NamedTuple):
    conv: jax.Array   # [B, K-1, conv_channels] rolling conv window
    ssm: jax.Array    # [B, H, P, N] recurrent state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_ch


def init_ssm(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d_in, n_heads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              2 * d_in + 2 * s.n_groups * s.d_state + n_heads,
                              dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_kernel, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, cfg.d_model, dtype),
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s = cfg.ssm
    d_in, n_heads, conv_ch = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    )


def _causal_conv(xbc, w, b, prior=None):
    """Depthwise causal conv over time. xbc: [B,T,C], w: [K,C]."""
    k = w.shape[0]
    if prior is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prior.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                # [B,T+K-1,C]
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(k))
    new_prior = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out + b[None, None]), new_prior


def _split(p, x, cfg: ModelConfig):
    s = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * s.n_groups * s.d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def _heads(xbc, cfg: ModelConfig):
    s = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    xs = xbc[..., :d_in]
    bc = xbc[..., d_in:]
    b_mat = bc[..., :s.n_groups * s.d_state]
    c_mat = bc[..., s.n_groups * s.d_state:]
    lead = xs.shape[:-1]
    xs = xs.reshape(*lead, n_heads, s.head_dim)
    b_mat = b_mat.reshape(*lead, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(*lead, s.n_groups, s.d_state)
    # broadcast groups over heads
    rep = n_heads // s.n_groups
    b_mat = jnp.repeat(b_mat, rep, axis=-2)
    c_mat = jnp.repeat(c_mat, rep, axis=-2)
    return xs, b_mat, c_mat


def ssd_chunked(xs, dt, A, b_mat, c_mat, chunk: int,
                initial_state=None):
    """Chunked SSD scan.

    xs: [B,T,H,P]; dt: [B,T,H] (post-softplus); A: [H] (negative);
    b_mat/c_mat: [B,T,H,N]. Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    bsz, t, h, p_dim = xs.shape
    n = b_mat.shape[-1]
    q = min(chunk, t)
    t_real = t
    pad = (-t) % q
    if pad:
        # zero-pad time: x=B=0 ⇒ no state contribution; dt=0 ⇒ decay=1,
        # so the final state is unaffected; pad outputs sliced off below
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    nc = t // q

    xs = xs.reshape(bsz, nc, q, h, p_dim).astype(jnp.float32)
    dt = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bm = b_mat.reshape(bsz, nc, q, h, n).astype(jnp.float32)
    cm = c_mat.reshape(bsz, nc, q, h, n).astype(jnp.float32)

    da = dt * A[None, None, None]                           # [B,c,Q,H]
    cum = jnp.cumsum(da, axis=2)
    # intra-chunk (diagonal) term: attention-like with decay kernel
    li = cum[:, :, :, None, :]                              # i index
    lj = cum[:, :, None, :, :]                              # j index
    seg = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))            # [B,c,Q,Q,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cm, bm)
    w = cb * seg * dt[:, :, None, :, :]                     # [B,c,i,j,H]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w, xs)

    # chunk states: decay from j to end of chunk
    decay_end = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                        dt * decay_end, bm, xs)             # [B,c,H,P,N]
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # [B,c,H]

    def scan_fn(s_prev, inp):
        st, dec = inp                                       # [B,H,P,N], [B,H]
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = (jnp.zeros((bsz, h, p_dim, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final, prev_states = xscan(
        scan_fn, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                # [B,c,H,P,N]

    # inter-chunk term: read previous chunk state with decay to position i
    in_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))           # [B,c,Q,H]
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", cm, prev_states, in_decay)
    y = (y_diag + y_off).reshape(bsz, t, h, p_dim)
    return y[:, :t_real], final


def ssm_train(p, x, cfg: ModelConfig, state: SSMState = None,
              return_state: bool = False):
    """Full-sequence Mamba2 block. x: [B,T,d] → [B,T,d]."""
    s = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    b, t, _ = x.shape
    z, xbc, dt = _split(p, x, cfg)
    prior = state.conv if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], prior)
    xs, b_mat, c_mat = _heads(xbc, cfg)
    # shard SSD heads over `model` so the [B,c,Q,Q,H] intra-chunk kernel
    # splits across TP (H is divisible by 16 for both assigned SSM archs)
    xs = shard(xs, "batch", "seq", "heads", None)
    b_mat = shard(b_mat, "batch", "seq", "heads", None)
    c_mat = shard(c_mat, "batch", "seq", "heads", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    init = state.ssm if state is not None else None
    y, final = ssd_chunked(xs, dt, A, b_mat, c_mat, s.chunk_size, init)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = rms_norm_gated(y, z, p["norm_w"])
    out = y @ p["out_proj"]
    if return_state:
        return out, SSMState(conv=new_conv, ssm=final)
    return out


def ssm_decode(p, x, cfg: ModelConfig, state: SSMState
               ) -> Tuple[jax.Array, SSMState]:
    """One decode step. x: [B,d] → (y [B,d], state)."""
    s = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    b, _ = x.shape
    z, xbc, dt = _split(p, x[:, None, :], cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    xs, b_mat, c_mat = _heads(xbc[:, 0], cfg)               # [B,H,P],[B,H,N]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None])                           # [B,H]
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, b_mat.astype(jnp.float32),
                     xs.astype(jnp.float32))
    ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", c_mat.astype(jnp.float32), ssm)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, d_in).astype(x.dtype)
    y = rms_norm_gated(y, z[:, 0], p["norm_w"])
    return y @ p["out_proj"], SSMState(conv=new_conv, ssm=ssm)
