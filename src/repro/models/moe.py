"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch,
EP sharding.

Dispatch is SORT-based (MaxText/MegaBlocks style): tokens are ordered by
destination expert, placed into a [E, capacity, d] buffer (overflow slots
drop), expert FFNs run as batched einsums with the expert axis sharded over
`model` (EP — XLA inserts the all-to-alls), and outputs are gathered back by
inverse permutation. This is O(T·k·log) routing + O(E·C·d·ff) compute —
the naive one-hot dispatch tensor [T, E, C] would be O(T·E·C) and is
intractable at deepseek-v3 scale (1M tokens × 256 experts × 40k capacity).

Supports shared (always-on) experts and sigmoid gating (DeepSeek-V3 style).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import apply_mlp, init_mlp
from repro.runtime.sharding import shard


def init_moe(key, cfg: ModelConfig, dtype):
    mo = cfg.moe
    keys = jax.random.split(key, 4)
    d, ff, e = cfg.d_model, mo.d_ff_expert, mo.n_experts
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    fscale = 1.0 / jnp.sqrt(jnp.float32(ff))
    p = {
        "router": (jax.random.normal(keys[0], (d, e), jnp.float32)
                   * scale).astype(jnp.float32),          # router in f32
        "wi": (jax.random.normal(keys[1], (e, d, ff), jnp.float32)
               * scale).astype(dtype),
        "wo": (jax.random.normal(keys[2], (e, ff, d), jnp.float32)
               * fscale).astype(dtype),
    }
    if cfg.act == "swiglu":
        p["wg"] = (jax.random.normal(keys[3], (e, d, ff), jnp.float32)
                   * scale).astype(dtype)
    if mo.n_shared > 0:
        skeys = jax.random.split(jax.random.fold_in(key, 7), mo.n_shared)
        p["shared"] = [init_mlp(sk, cfg, ff, dtype) for sk in skeys]
    return p


def _expert_ffn(p, x, act: str):
    """x: [E, C, d] → [E, C, d] with per-expert weights."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"])
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["wg"])) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    h = shard(h, "experts", None, "ff")
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def apply_moe_ep_shardmap(p, x, cfg: ModelConfig, mesh,
                          gating_override: str = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map + all_to_all (§Perf beyond-paper).

    The XLA-propagated sort-based dispatch all-gathers the [T·k, d] update
    payload across shards (the dominant collective of the 671B train cell).
    Here each data shard routes its LOCAL tokens, packs per-expert-shard
    send buffers, and a single all_to_all over `model` moves exactly the
    token payloads — the textbook EP schedule. Requires n_experts and
    tokens divisible by the model-axis size.
    """
    from repro.runtime.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mo: MoEConfig = cfg.moe
    b, t, d = x.shape
    e, k = mo.n_experts, mo.top_k
    ep = mesh.shape["model"]
    e_loc = e // ep
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                       and b % mesh.shape[a] == 0)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    n_loc = (b // dp) * t                       # local tokens per data shard
    # per-(src shard → dst shard) capacity, multiple of 8
    cap = max(-(-int(n_loc * k * mo.capacity_factor) // ep), 8)
    cap = -(-cap // 8) * 8
    gating = gating_override or ("sigmoid" if mo.n_shared else "softmax")

    def local_fn(x_l, router, wi, wg, wo):
        xt = x_l.reshape(-1, d)                               # [n_loc, d]
        logits = xt.astype(jnp.float32) @ router
        scores = (jax.nn.sigmoid(logits) if gating == "sigmoid"
                  else jax.nn.softmax(logits, axis=-1))
        topv, topi = jax.lax.top_k(scores, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)                             # [n_loc·k]
        dest_shard = flat_e // e_loc
        # position within the (dest shard) send queue
        sort_idx = jnp.argsort(dest_shard, stable=True)
        sorted_dst = dest_shard[sort_idx]
        counts = jnp.bincount(dest_shard, length=ep)
        offs = jnp.cumsum(counts) - counts
        pos_in = jnp.arange(n_loc * k, dtype=jnp.int32) - offs[sorted_dst]
        keep = pos_in < cap
        send_slot = jnp.where(keep, sorted_dst * cap + pos_in, ep * cap)
        tok_of = sort_idx // k
        send = jnp.zeros((ep * cap + 8, d), x_l.dtype)
        send = send.at[send_slot].set(xt[tok_of])
        send_eid = jnp.full((ep * cap + 8,), -1, jnp.int32)
        send_eid = send_eid.at[send_slot].set(flat_e[sort_idx])
        # all_to_all: [ep, cap, d] send → [ep, cap, d] recv (per dst shard)
        recv = jax.lax.all_to_all(send[:ep * cap].reshape(ep, cap, d),
                                  "model", 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(
            send_eid[:ep * cap].reshape(ep, cap), "model", 0, 0,
            tiled=False)
        rx = recv.reshape(ep * cap, d)                        # [R, d]
        re_id = recv_eid.reshape(ep * cap)
        # second-stage LOCAL sort-dispatch: each local expert computes only
        # its own rows (a one-hot dense dispatch would multiply the FFN
        # flops by E_loc — measured 29× on deepseek-v3 before this fix)
        r_tot = ep * cap
        le = jnp.where(re_id >= 0, re_id % e_loc, e_loc)      # e_loc = trash
        s_idx = jnp.argsort(le, stable=True)
        s_le = le[s_idx]
        cnts = jnp.bincount(le, length=e_loc + 1)
        offs = jnp.cumsum(cnts) - cnts
        cap2 = -(-int(r_tot // max(e_loc, 1) * 1.25) // 8) * 8
        pos2 = jnp.arange(r_tot, dtype=jnp.int32) - offs[s_le]
        ok2 = (pos2 < cap2) & (s_le < e_loc)
        dest2 = jnp.where(ok2, s_le * cap2 + pos2, e_loc * cap2)
        buf = jnp.zeros((e_loc * cap2 + 1, d), rx.dtype)
        buf = buf.at[dest2].set(rx[s_idx])
        ex_in = buf[:-1].reshape(e_loc, cap2, d)              # [E_loc,C2,d]
        h = jnp.einsum("ecd,edf->ecf", ex_in, wi)
        if cfg.act == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, wg)) * h
        elif cfg.act == "gelu":
            h = jax.nn.gelu(h)
        else:
            h = jnp.square(jax.nn.relu(h))
        ex_out = jnp.einsum("ecf,efd->ecd", h, wo)
        out_buf = jnp.concatenate(
            [ex_out.reshape(e_loc * cap2, d),
             jnp.zeros((1, d), ex_out.dtype)], 0)
        inv2 = jnp.zeros((r_tot,), jnp.int32).at[s_idx].set(
            dest2.astype(jnp.int32))
        y_rx = out_buf[inv2]                                  # [R, d]
        # return payloads to source shards
        back = jax.lax.all_to_all(y_rx.reshape(ep, cap, d), "model", 0, 0,
                                  tiled=False).reshape(ep * cap, d)
        back = jnp.concatenate([back, jnp.zeros((8, d), back.dtype)], 0)
        y_sorted = back[send_slot]                            # [n_loc·k, d]
        gate_sorted = (topv.reshape(-1)[sort_idx] * keep)[:, None]
        contrib = y_sorted.astype(jnp.float32) * gate_sorted
        y = jnp.zeros((n_loc, d), jnp.float32).at[tok_of].add(contrib)
        # aux loss (local fractions; mean over shards via psum)
        probs = jax.nn.softmax(logits, axis=-1)
        f_e = jnp.bincount(flat_e, length=e).astype(jnp.float32) / (n_loc * k)
        aux = e * jnp.sum(f_e * jnp.mean(probs, axis=0)) * mo.aux_loss_weight
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        return y.reshape(x_l.shape).astype(x_l.dtype), aux

    scalarP = P()
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), scalarP,
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), scalarP),
        check_vma=False,
    )(x, p["router"], p["wi"], p.get("wg", p["wi"]), p["wo"])

    if mo.n_shared > 0:
        for sp in p["shared"]:
            y = y + apply_mlp(sp, x, cfg.act)
    return y, aux


def apply_moe(p, x, cfg: ModelConfig, gating_override: str = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,T,d] → (y [B,T,d], aux_loss scalar)."""
    mo: MoEConfig = cfg.moe
    b, t, d = x.shape
    n_tok = b * t
    e, k = mo.n_experts, mo.top_k
    cap = max(-(-int(n_tok * k * mo.capacity_factor) // e), 8)
    cap = -(-cap // 8) * 8                                 # round up to 8

    xt = x.reshape(n_tok, d)
    logits = xt.astype(jnp.float32) @ p["router"]          # [T, E]
    gating = gating_override or ("sigmoid" if mo.n_shared else "softmax")
    if gating == "sigmoid":                                # deepseek-v3 style
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(scores, k)                  # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ----
    flat_e = topi.reshape(-1)                              # [T·k]
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=e)                # [E]
    offsets = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n_tok * k, dtype=jnp.int32) - offsets[sorted_e]
    keep_sorted = pos_in_e < cap
    trash = e * cap                                        # overflow slot
    dest_sorted = jnp.where(keep_sorted, sorted_e * cap + pos_in_e, trash)
    token_of = sort_idx // k                               # source token

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dest_sorted].set(xt[token_of])
    ex_in = shard(buf[:-1].reshape(e, cap, d), "experts", None, None)
    ex_out = _expert_ffn(p, ex_in, cfg.act)
    out_buf = jnp.concatenate(
        [ex_out.reshape(e * cap, d), jnp.zeros((1, d), ex_out.dtype)], 0)

    # inverse permutation → per-(token, choice) output rows
    dest = jnp.zeros((n_tok * k,), jnp.int32).at[sort_idx].set(
        dest_sorted.astype(jnp.int32))
    y = (out_buf[dest].reshape(n_tok, k, d).astype(jnp.float32)
         * topv[..., None]).sum(axis=1).astype(x.dtype)

    # load-balancing aux loss (Switch): E * Σ_e f_e · P_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = counts.astype(jnp.float32) / (n_tok * k)
    frac_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_prob) * mo.aux_loss_weight

    if mo.n_shared > 0:
        ys = xt.reshape(b, t, d)
        for sp in p["shared"]:
            y = y + apply_mlp(sp, ys, cfg.act).reshape(n_tok, d)
    return y.reshape(b, t, d), aux
