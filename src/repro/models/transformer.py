"""Unified model zoo: dense / MoE / MLA-MoE / SSM / hybrid / enc-dec.

One `Model` facade per architecture config with three entry points:

  train_logits(params, batch)       — full causal pass (+ aux losses)
  prefill(params, batch)            — prompt pass, builds the DecodeState
                                      (one-shot static pruning happens here)
  decode_step(params, state, tok)   — one token; UniCAIM dynamic pruning +
                                      static eviction live in this step

Layers are scanned (stacked params) so compile time is O(1) in depth; the
remat policy wraps the scan body.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.flags import xscan

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import cache as kvcache
from repro.core.cache import KVCache, init_cache
from repro.models import layers as L
from repro.models.attention_layer import (attention_decode,
                                          attention_decode_stacked,
                                          attention_prefill,
                                          attention_prefill_chunk,
                                          attention_train, cross_attention,
                                          encode_cross_kv, init_attention)
from repro.models.mla import (init_mla, mla_decode, mla_decode_stacked,
                              mla_prefill, mla_train)
from repro.models.moe import apply_moe, apply_moe_ep_shardmap, init_moe
from repro.models.ssm import (SSMState, init_ssm, init_ssm_state, ssm_decode,
                              ssm_train)
from repro.runtime.sharding import shard


class DecodeState(NamedTuple):
    kv: Optional[KVCache]            # stacked [L_attn, ...]
    ssm: Optional[SSMState]          # stacked [L_ssm, ...]
    cross: Optional[Tuple[jax.Array, jax.Array]]  # [L_dec, B, Hk, S, dh]


class PrefillChunkState(NamedTuple):
    """Streaming workspace for a time-sliced (chunked) prefill.

    Fixed-size per-layer prompt K/V buffers plus the running accumulated
    column sums, sized to the prompt's shape bucket. Chunks write rows
    [row0, row0+C) and attend causally over the prefix; after the last
    chunk `Model.prefill_finalize` runs the one-shot static pruning over
    the full buffers — numerically identical to a whole-prompt prefill,
    but dispatchable in slices interleaved with decode blocks."""
    k: jax.Array                     # [L_attn, B, Hk, N_bucket, dh]
    v: jax.Array                     # [L_attn, B, Hk, N_bucket, dv]
    acc: jax.Array                   # [L_attn, B, Hk, N_bucket] f32


# ---------------------------------------------------------------------------
# Per-lane DecodeState surgery (continuous batching).
#
# Every stacked state array carries layers on axis 0 and batch on axis 1, so
# one lane of a live batched DecodeState can be sliced out or replaced by a
# freshly prefilled batch-1 state without disturbing the other lanes. These
# are jit-safe (the lane index may be a traced scalar) and cover all three
# state families: the KV cache (every field, via the matching core/cache
# helpers), SSM recurrent state, and enc-dec cross K/V.
# ---------------------------------------------------------------------------


def lane_slice(state: DecodeState, lane) -> DecodeState:
    """One lane of a batched DecodeState as a batch-1 DecodeState."""
    def sl(a):
        return jax.lax.dynamic_slice_in_dim(a, lane, 1, axis=1)
    kv = (kvcache.lane_slice(state.kv, lane, batch_axis=1)
          if state.kv is not None else None)
    return DecodeState(kv=kv, ssm=jax.tree.map(sl, state.ssm),
                       cross=jax.tree.map(sl, state.cross))


def lane_insert(state: DecodeState, lane, fresh: DecodeState) -> DecodeState:
    """Splice a batch-1 `fresh` state (e.g. from `prefill_one`) into lane
    `lane` of a live batched DecodeState."""
    def ins(a, f):
        return jax.lax.dynamic_update_slice_in_dim(
            a, f.astype(a.dtype), lane, axis=1)
    kv = (kvcache.lane_insert(state.kv, lane, fresh.kv, batch_axis=1)
          if state.kv is not None else None)
    return DecodeState(kv=kv, ssm=jax.tree.map(ins, state.ssm, fresh.ssm),
                       cross=jax.tree.map(ins, state.cross, fresh.cross))


def lanes_insert(state: DecodeState, src, fresh: DecodeState) -> DecodeState:
    """Multi-lane splice: scatter rows of a batch-G `fresh` DecodeState
    (e.g. from `Model.prefill_group`) into a live batched state in ONE
    vectorized pass over the whole pytree — every `KVCache` field
    (including the quantized mirrors/scales and accumulated scores), SSM
    recurrent state, and enc-dec cross K/V.

    `src` is an int32 [B_live] map from live lane to `fresh` row: lane b
    takes `fresh` row `src[b]` when `src[b] >= 0` and keeps its current
    contents at -1 — so one compiled program covers every group size.
    Bit-identical to applying `lane_insert` once per mapped lane."""
    src = jnp.asarray(src, jnp.int32)
    keep = src < 0
    idx = jnp.maximum(src, 0)

    def ins(a, f):
        g = jnp.take(f.astype(a.dtype), idx, axis=1)
        m = keep.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, g)

    kv = (kvcache.lanes_insert(state.kv, src, fresh.kv, batch_axis=1)
          if state.kv is not None else None)
    return DecodeState(kv=kv, ssm=jax.tree.map(ins, state.ssm, fresh.ssm),
                       cross=jax.tree.map(ins, state.cross, fresh.cross))


def lane_select(active: jax.Array, new: DecodeState,
                old: DecodeState) -> DecodeState:
    """Per-lane merge: lanes where `active` ([B] bool) take `new`, the rest
    keep `old` — lets finished lanes stop contributing state writes inside a
    scanned decode block (in-device termination)."""
    def sel(n, o):
        m = active.reshape((1, active.shape[0]) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# per-layer init/apply
# ---------------------------------------------------------------------------


def _moe(pm, h, cfg: ModelConfig):
    """MoE dispatch: shard_map EP path when enabled + mesh active and
    divisibility holds; XLA sort-based dispatch otherwise."""
    if cfg.moe_ep:
        from repro.runtime.sharding import active_mesh
        mesh = active_mesh()
        if (mesh is not None and "model" in mesh.shape
                and cfg.moe.n_experts % mesh.shape["model"] == 0):
            return apply_moe_ep_shardmap(pm, h, cfg, mesh)
    return apply_moe(pm, h, cfg)


def _init_block(key, cfg: ModelConfig, dtype, kind: str):
    """One residual block. kind: dense | moe | mla_dense | mla_moe | ssm |
    encdec_enc | encdec_dec."""
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if kind == "ssm":
        p["norm"] = L.init_norm(cfg, dtype)
        p["ssm"] = init_ssm(ks[0], cfg, dtype)
        return p
    p["ln1"] = L.init_norm(cfg, dtype)
    p["ln2"] = L.init_norm(cfg, dtype)
    if kind.startswith("mla"):
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attention(ks[0], cfg, dtype)
    if kind == "encdec_dec":
        p["ln_x"] = L.init_norm(cfg, dtype)
        p["xattn"] = init_attention(ks[1], cfg, dtype)
    if kind.endswith("moe"):
        p["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        d_ff = cfg.d_ff if cfg.moe is None else cfg.moe.d_ff_dense
        p["mlp"] = L.init_mlp(ks[3], cfg, d_ff or cfg.d_ff, dtype)
    return p


def _block_train(p, x, cfg: ModelConfig, positions, kind: str,
                 cross_kv=None, causal: bool = True):
    """Residual block, full sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = L.apply_norm(p["norm"], x, cfg.norm)
        return x + ssm_train(p["ssm"], h, cfg), aux
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    if kind.startswith("mla"):
        a = mla_train(p["attn"], h, cfg, positions)
    else:
        a = attention_train(p["attn"], h, cfg, positions, causal=causal)
    x = x + a
    if kind == "encdec_dec":
        h = L.apply_norm(p["ln_x"], x, cfg.norm)
        x = x + cross_attention(p["xattn"], h, cross_kv, cfg)
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if kind.endswith("moe"):
        y, aux = _moe(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg.act)
    return x + y, aux


def _block_prefill(p, x, cfg, positions, prune, cache, kind: str,
                   cross_kv=None, length=None):
    """Residual block prompt pass with cache fill. Returns (x, cache)."""
    if kind == "ssm":
        h = L.apply_norm(p["norm"], x, cfg.norm)
        y, st = ssm_train(p["ssm"], h, cfg, cache, return_state=True)
        return x + y, st
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    if kind.startswith("mla"):
        a, cache = mla_prefill(p["attn"], h, cfg, positions, prune, cache,
                               length=length)
    else:
        a, cache = attention_prefill(p["attn"], h, cfg, positions, prune,
                                     cache, length=length)
    x = x + a
    if kind == "encdec_dec":
        h = L.apply_norm(p["ln_x"], x, cfg.norm)
        x = x + cross_attention(p["xattn"], h, cross_kv, cfg)
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if kind.endswith("moe"):
        y, _ = _moe(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def _block_prefill_chunk(p, x, cfg, prune, bufs: PrefillChunkState,
                         kind: str, positions, row0, length):
    """Residual block over one prefill chunk, streaming K/V into `bufs`.
    x: [B,C,d]. Returns (x, bufs). Attention-only kinds (dense/moe)."""
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    a, k_buf, v_buf, acc = attention_prefill_chunk(
        p["attn"], h, cfg, positions, prune, bufs.k, bufs.v, bufs.acc,
        row0, length)
    x = x + a
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if kind.endswith("moe"):
        y, _ = _moe(p["moe"], h, cfg)
    else:
        y = L.apply_mlp(p["mlp"], h, cfg.act)
    return x + y, PrefillChunkState(k_buf, v_buf, acc)


def _block_decode_stacked(p, x, cfg, prune, kv, li, kind: str, window,
                          active):
    """Residual block, one token, writing layer `li` of the stacked cache
    IN PLACE (scatter/windowed-row writes — no per-layer cache copy).
    x: [B,d]. Returns (x, stacked cache). Attention-only kinds
    (dense/moe GQA and the mla_* latent-cache pair)."""
    h = L.apply_norm(p["ln1"], x[:, None, :], cfg.norm)[:, 0]
    if kind.startswith("mla"):
        a, kv = mla_decode_stacked(p["attn"], h, cfg, kv, li, prune,
                                   window, active)
    else:
        a, kv = attention_decode_stacked(p["attn"], h, cfg, kv, li, prune,
                                         window, active)
    x = x + a
    h = L.apply_norm(p["ln2"], x[:, None, :], cfg.norm)[:, 0]
    if kind.endswith("moe"):
        y, _ = _moe(p["moe"], h[:, None, :], cfg)
        y = y[:, 0]
    else:
        y = L.apply_mlp(p["mlp"], h[:, None, :], cfg.act)[:, 0]
    return x + y, kv


def _block_decode(p, x, cfg, prune, cache, kind: str, cross_kv=None):
    """Residual block, one token. x: [B,d]. Returns (x, cache)."""
    if kind == "ssm":
        h = L.apply_norm(p["norm"], x[:, None, :], cfg.norm)[:, 0]
        y, st = ssm_decode(p["ssm"], h, cfg, cache)
        return x + y, st
    h = L.apply_norm(p["ln1"], x[:, None, :], cfg.norm)[:, 0]
    if kind.startswith("mla"):
        a, cache = mla_decode(p["attn"], h, cfg, cache, prune)
    else:
        a, cache = attention_decode(p["attn"], h, cfg, cache, prune)
    x = x + a
    if kind == "encdec_dec":
        h = L.apply_norm(p["ln_x"], x[:, None, :], cfg.norm)
        x = x + cross_attention(p["xattn"], h, cross_kv, cfg)[:, 0]
    h = L.apply_norm(p["ln2"], x[:, None, :], cfg.norm)[:, 0]
    if kind.endswith("moe"):
        y, _ = _moe(p["moe"], h[:, None, :], cfg)
        y = y[:, 0]
    else:
        y = L.apply_mlp(p["mlp"], h[:, None, :], cfg.act)[:, 0]
    return x + y, cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Family-dispatching model facade (pure functions; params are pytrees)."""

    def __init__(self, cfg: ModelConfig, prune: PruneConfig,
                 remat: bool = False, decode_slots: Optional[int] = None,
                 remat_policy: str = "nothing"):
        cfg_ok = cfg.family in ("dense", "moe", "mla_moe", "ssm", "hybrid",
                                "encdec")
        assert cfg_ok, cfg.family
        self.cfg = cfg
        self.prune = prune
        self.remat = remat
        # 'nothing' = full recompute in bwd (min memory); 'dots' = keep
        # matmul outputs (no recompute of the big GEMMs — §Perf knob)
        self.remat_policy = remat_policy
        # decode cache size: the assigned shape's seq_len for dry-run cells,
        # or the paper budget H+M when the technique caps the cache
        self.decode_slots = decode_slots or prune.slots

    def _ckpt_policy(self):
        return {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        }[self.remat_policy]

    # -- segments ----------------------------------------------------------

    def _segments(self):
        """[(kind, n_layers)] for the main stack."""
        cfg = self.cfg
        if cfg.family == "dense":
            return [("dense", cfg.num_layers)]
        if cfg.family == "moe":
            return [("moe", cfg.num_layers)]
        if cfg.family == "mla_moe":
            k = cfg.moe.dense_first_k
            return [("mla_dense", k), ("mla_moe", cfg.num_layers - k)]
        if cfg.family == "ssm":
            return [("ssm", cfg.num_layers)]
        if cfg.family == "encdec":
            return [("encdec_enc", cfg.enc_layers),
                    ("encdec_dec", cfg.dec_layers)]
        if cfg.family == "hybrid":
            return [("hybrid", cfg.num_layers)]
        raise ValueError(cfg.family)

    def attn_layer_count(self) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        if cfg.family == "hybrid":
            return cfg.num_layers // cfg.attn_period
        if cfg.family == "encdec":
            return cfg.dec_layers
        return cfg.num_layers

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg.param_dtype)
        keys = jax.random.split(key, 10)
        params: Dict[str, Any] = {
            "embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": L.init_norm(cfg, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                             cfg.vocab_size, dt)
        if cfg.frontend != "none":
            params["frontend_adapter"] = L.dense_init(
                keys[2], cfg.d_model, cfg.d_model, dt)
        if cfg.family == "hybrid":
            n_groups = cfg.num_layers // cfg.attn_period
            rem = cfg.num_layers - n_groups * cfg.attn_period
            params["ssm_groups"] = _stack_init(
                lambda k: _stack_init(
                    lambda k2: _init_block(k2, cfg, dt, "ssm"),
                    k, cfg.attn_period),
                keys[3], n_groups)
            if rem:
                params["ssm_tail"] = _stack_init(
                    lambda k: _init_block(k, cfg, dt, "ssm"), keys[4], rem)
            params["shared_attn"] = _init_block(keys[5], cfg, dt, "dense")
            return params
        segs = self._segments()
        for i, (kind, n) in enumerate(segs):
            if n == 0:
                continue
            params[f"seg{i}_{kind}"] = _stack_init(
                lambda k, kind=kind: _init_block(k, cfg, dt, kind),
                keys[3 + i], n)
        if cfg.mtp_depth > 0:
            params["mtp"] = {
                "proj": L.dense_init(keys[8], 2 * cfg.d_model, cfg.d_model, dt),
                "norm": L.init_norm(cfg, dt),
                "block": _init_block(keys[9], cfg, dt, "mla_dense"
                                     if cfg.mla else "dense"),
            }
        return params

    # -- embeddings ---------------------------------------------------------

    def _embed_tokens(self, params, tokens):
        x = params["embed"][tokens]
        return x.astype(_dtype(self.cfg.compute_dtype))

    def _logits(self, params, x):
        x = L.apply_norm(params["final_norm"], x, self.cfg.norm)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
        return shard(logits, "batch", "seq", "vocab")

    def _prepend_frontend(self, params, batch, x):
        cfg = self.cfg
        if cfg.frontend == "none" or cfg.family == "encdec":
            return x, 0
        emb = batch[f"{cfg.frontend}_embed"].astype(x.dtype)
        emb = emb @ params["frontend_adapter"]
        return jnp.concatenate([emb, x], axis=1), emb.shape[1]

    # -- scan helpers --------------------------------------------------------

    def _scan_train(self, stacked, x, positions, kind, cross_kv=None,
                    causal=True):
        cfg = self.cfg

        def body(x, pl):
            y, aux = _block_train(pl, x, cfg, positions, kind,
                                  cross_kv=cross_kv, causal=causal)
            return y, aux

        if self.remat:
            body = jax.checkpoint(body, policy=self._ckpt_policy())
        x, auxs = xscan(body, x, stacked)
        return x, jnp.sum(auxs)

    # -- train ---------------------------------------------------------------

    def head_matrix(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    def train_hidden(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Backbone pass → (post-final-norm hidden [B,T,d], aux). Lets the
        loss chunk the vocab projection (§Perf) instead of materialising
        [B,T,V] logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        aux_total = jnp.zeros((), jnp.float32)

        if cfg.family == "encdec":
            enc = batch["enc_embed"].astype(_dtype(cfg.compute_dtype))
            pos_e = jnp.arange(enc.shape[1])[None]
            enc = enc + L.sinusoidal(pos_e, cfg.d_model).astype(enc.dtype)
            enc, aux = self._scan_train(params["seg0_encdec_enc"], enc,
                                        pos_e, "encdec_enc", causal=False)
            aux_total += aux
            x = self._embed_tokens(params, tokens)
            pos = jnp.arange(t)[None]
            if cfg.pos == "sinusoidal":
                x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)
            # cross K/V from encoder output, per decoder layer
            xkv = jax.vmap(lambda pl: encode_cross_kv(pl["xattn"], enc, cfg)
                           )(params["seg1_encdec_dec"])
            def body(x, inp):
                pl, ckv = inp
                y, aux = _block_train(pl, x, cfg, pos, "encdec_dec",
                                      cross_kv=ckv)
                return y, aux
            if self.remat:
                body = jax.checkpoint(body, policy=self._ckpt_policy())
            x, auxs = xscan(body, x, (params["seg1_encdec_dec"], xkv))
            self._hidden_for_mtp = x
            h = L.apply_norm(params["final_norm"], x, cfg.norm)
            return h, aux_total + jnp.sum(auxs)

        x = self._embed_tokens(params, tokens)
        x, n_front = self._prepend_frontend(params, batch, x)
        x = shard(x, "batch", "seq", None)
        pos = jnp.arange(x.shape[1])[None]
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)

        if cfg.family == "hybrid":
            x, aux = self._hybrid_train(params, x, pos)
            aux_total += aux
        else:
            for i, (kind, n) in enumerate(self._segments()):
                if n == 0:
                    continue
                x, aux = self._scan_train(params[f"seg{i}_{kind}"], x, pos,
                                          kind)
                aux_total += aux
        if n_front:
            x = x[:, n_front:]
        self._hidden_for_mtp = x
        h = L.apply_norm(params["final_norm"], x, cfg.norm)
        return h, aux_total

    def train_logits(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """batch: {'tokens': [B,T], optional '<frontend>_embed',
        'enc_embed'} → (logits [B,T,V], aux)."""
        h, aux_total = self.train_hidden(params, batch)
        head = self.head_matrix(params)
        logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
        return shard(logits, "batch", "seq", "vocab"), aux_total

    def train_outputs(self, params, batch) -> Dict[str, jax.Array]:
        """Main logits + aux + (optional) MTP logits from one backbone pass."""
        logits, aux = self.train_logits(params, batch)
        out = {"logits": logits, "aux": aux}
        cfg = self.cfg
        if cfg.mtp_depth > 0 and "mtp" in params:
            tokens = batch["tokens"]
            h = self._hidden_for_mtp[:, :-1]
            e_next = self._embed_tokens(params, tokens[:, 1:])
            z = (jnp.concatenate([h, e_next], axis=-1)
                 @ params["mtp"]["proj"])
            pos = jnp.arange(z.shape[1])[None]
            z, _ = _block_train(params["mtp"]["block"], z, cfg, pos,
                                "mla_dense" if cfg.mla else "dense")
            z = L.apply_norm(params["mtp"]["norm"], z, cfg.norm)
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            out["mtp_logits"] = (z.astype(jnp.float32)
                                 @ head.astype(jnp.float32))
        return out

    def _hybrid_train(self, params, x, pos):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)

        def group_body(x, inp):
            gp = inp
            x, _ = _block_train(params["shared_attn"], x, cfg, pos, "dense")
            def inner(x, pl):
                y, a = _block_train(pl, x, cfg, pos, "ssm")
                return y, a
            x, _ = xscan(inner, x, gp)
            return x, jnp.zeros(())

        body = group_body
        if self.remat:
            body = jax.checkpoint(body, policy=self._ckpt_policy())
        x, _ = xscan(body, x, params["ssm_groups"])
        if "ssm_tail" in params:
            def inner(x, pl):
                y, a = _block_train(pl, x, cfg, pos, "ssm")
                return y, a
            x, _ = xscan(inner, x, params["ssm_tail"])
        return x, aux

    # -- decode state ---------------------------------------------------------

    def init_decode_state(self, batch_size: int, slots: Optional[int] = None,
                          cross_len: int = 0) -> DecodeState:
        cfg = self.cfg
        slots = slots or self.decode_slots
        dt = _dtype(cfg.compute_dtype)
        kv = None
        ssm = None
        cross = None
        n_attn = self.attn_layer_count()
        if n_attn > 0:
            if cfg.mla is not None:
                one = init_cache(batch_size, 1, cfg.mla.latent_dim, slots,
                                 self.prune, dt, latent=True)
            else:
                one = init_cache(batch_size, cfg.n_kv_heads, cfg.head_dim,
                                 slots, self.prune, dt)
            kv = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_attn,) + a.shape), one)
        if cfg.family in ("ssm", "hybrid"):
            n_ssm = cfg.num_layers
            one = init_ssm_state(cfg, batch_size, jnp.float32)
            ssm = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_ssm,) + a.shape), one)
        if cfg.family == "encdec" and cross_len > 0:
            cross = (jnp.zeros((cfg.dec_layers, batch_size, cfg.n_kv_heads,
                                cross_len, cfg.head_dim), dt),) * 2
        return DecodeState(kv=kv, ssm=ssm, cross=cross)

    # -- prefill ---------------------------------------------------------------

    def prefill(self, params, batch) -> Tuple[jax.Array, DecodeState]:
        """Prompt pass with one-shot static pruning.
        Returns (last-position logits [B,V], DecodeState).

        `batch["length"]` ([B] int32, optional) marks true per-lane prompt
        lengths when `tokens` is right-padded to a shape-stable bucket:
        pad positions neither attend, accumulate charge-domain mass, nor
        enter the static top-k, the cache records the real length, and the
        returned logits come from the last *valid* position of each lane.
        Only attention families support it (SSM/hybrid recurrent state and
        the enc-dec path would absorb pad tokens)."""
        cfg = self.cfg
        prune = self.prune
        tokens = batch["tokens"]
        length = batch.get("length")
        b, t = tokens.shape

        if cfg.family == "encdec":
            if length is not None:
                raise ValueError("bucketed prefill: encdec unsupported")
            return self._prefill_encdec(params, batch)
        if length is not None and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"bucketed prefill: {cfg.family} carries recurrent state "
                "that right-padded tokens would pollute")
        if length is not None:
            length = jnp.asarray(length, jnp.int32)

        x = self._embed_tokens(params, tokens)
        x, n_front = self._prepend_frontend(params, batch, x)
        # frontend positions sit at the FRONT and are always valid
        eff_len = None if length is None else length + n_front
        pos = jnp.arange(x.shape[1])[None]
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)
        state = self.init_decode_state(b)

        if cfg.family == "hybrid":
            x, state = self._prefill_hybrid(params, x, pos, state)
        elif cfg.family == "ssm":
            def body(x, inp):
                pl, st = inp
                y, st2 = _block_prefill(pl, x, cfg, pos, prune, st, "ssm")
                return y, st2
            x, new_ssm = xscan(body, x, (params["seg0_ssm"],
                                                state.ssm))
            state = state._replace(ssm=new_ssm)
        else:
            li = 0
            new_caches = []
            for i, (kind, n) in enumerate(self._segments()):
                if n == 0:
                    continue
                kv_seg = jax.tree.map(lambda a: a[li:li + n], state.kv)
                def body(x, inp, kind=kind):
                    pl, c = inp
                    y, c2 = _block_prefill(pl, x, cfg, pos, prune, c, kind,
                                           length=eff_len)
                    return y, c2
                x, kv_out = xscan(body, x,
                                         (params[f"seg{i}_{kind}"], kv_seg))
                new_caches.append(kv_out)
                li += n
            kv = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_caches)
            state = state._replace(kv=kv)
        if length is None:
            x_last = x[:, -1:]
        else:  # last *valid* position per lane, not the bucket's last pad
            idx = (length + n_front - 1)[:, None, None]
            x_last = jnp.take_along_axis(x, idx, axis=1)
        logits = self._logits(params, x_last)[:, 0]
        return logits, state

    def prefill_one(self, params, tokens,
                    length=None) -> Tuple[jax.Array, DecodeState]:
        """Prefill a single request. tokens: [t] (any t ≤ max_seq_len) →
        (logits [V], batch-1 DecodeState) ready for `lane_insert` into a
        live batched state.

        Each distinct `tokens` width traces/compiles its own program under
        jit. Serving engines bound that by right-padding the prompt to a
        small bucket set and passing the true `length` (scalar, may be
        traced): compile count is then ≤ len(buckets) regardless of
        traffic, and the masked program produces bit-identical logits and
        cache to a same-bucket full-batch prefill (`ServeLoop` does this
        by default; see `launch/serve.py:pad_to_bucket`)."""
        tokens = jnp.asarray(tokens)
        batch = {"tokens": tokens[None]}
        if length is not None:
            batch["length"] = jnp.asarray(length, jnp.int32).reshape(1)
        logits, state = self.prefill(params, batch)
        return logits[0], state

    def prefill_group(self, params, tokens,
                      lengths=None) -> Tuple[jax.Array, DecodeState]:
        """Batched admission prefill: G requests padded to one shared
        bucket in a single dispatch. tokens: [G, W]; lengths: [G] true
        prompt lengths (optional — omit for exact-width prompts). Returns
        (logits [G, V], batch-G DecodeState) ready for `lanes_insert`
        into a live batched state.

        Per-lane math is exactly `prefill`'s (prompts never attend across
        the batch axis), so each row is bit-identical to what `prefill_one`
        would produce for it alone — grouped admission is a pure dispatch-
        count optimization. Serving engines pad the group to a fixed row
        count (duplicating a real row) so one compiled program per bucket
        serves every group size; surplus rows are discarded by the
        `lanes_insert` source map."""
        batch = {"tokens": jnp.asarray(tokens)}
        if lengths is not None:
            batch["length"] = jnp.asarray(lengths, jnp.int32)
        return self.prefill(params, batch)

    def supports_bucketed_prefill(self) -> bool:
        """True-length-masked (right-padded) prefill needs the prompt pass
        to be attention-only: SSM/hybrid recurrence and the enc-dec path
        would absorb pad tokens into their state. Serving engines fall
        back to exact-length prefills for these families."""
        return self.cfg.family not in ("ssm", "hybrid", "encdec")

    # -- chunked (time-sliced) prefill ---------------------------------------

    def supports_chunked_prefill(self) -> bool:
        """Chunked admission covers the plain attention stacks; recurrent
        (ssm/hybrid), enc-dec, MLA-latent, and frontend models fall back
        to whole-prompt bucketed prefill."""
        cfg = self.cfg
        return (cfg.family in ("dense", "moe") and cfg.mla is None
                and cfg.frontend == "none")

    def init_prefill_chunk_state(self, batch_size: int,
                                 bucket: int) -> PrefillChunkState:
        """Empty streaming workspace for a prompt padded to `bucket`."""
        cfg = self.cfg
        assert self.supports_chunked_prefill(), cfg.family
        dt = _dtype(cfg.compute_dtype)
        n_attn = self.attn_layer_count()
        shape = (n_attn, batch_size, cfg.n_kv_heads, bucket, cfg.head_dim)
        return PrefillChunkState(k=jnp.zeros(shape, dt),
                                 v=jnp.zeros(shape, dt),
                                 acc=jnp.zeros(shape[:4], jnp.float32))

    def resume_prefill_chunk_state(self, k_rows, v_rows, acc_rows,
                                   bucket: int) -> PrefillChunkState:
        """Workspace for a suffix-only (prefix-cached) chunked prefill.

        k_rows/v_rows: ``[L_attn, Hk, p, dh]`` host or device rows;
        acc_rows: ``[L_attn, Hk, p]`` f32 accumulated column sums — the
        state a from-scratch chunked prefill holds after its first
        ``p / C`` chunks (see `launch/prefix_cache.RowsEntry`). Returns a
        batch-1 `PrefillChunkState` over `bucket` with rows [0, p) filled
        and the rest zero, ready for `prefill_chunk` calls starting at
        row p. Because a chunk's workspace writes depend only on tokens
        [0, row0 + C) — unwritten columns carry exactly-zero attention
        mass — resuming here and finalizing is bit-identical to running
        every chunk from row 0, for bf16 and int8 caches alike (the int8
        mirrors quantize only at `prefill_finalize`). The donor's bucket
        may differ from `bucket`: rows are bucket-width independent."""
        assert self.supports_chunked_prefill(), self.cfg.family
        pstate = self.init_prefill_chunk_state(1, bucket)
        p = int(k_rows.shape[-2])
        assert p <= bucket, (p, bucket)
        k = pstate.k.at[:, :, :, :p].set(
            jnp.asarray(k_rows, pstate.k.dtype)[:, None])
        v = pstate.v.at[:, :, :, :p].set(
            jnp.asarray(v_rows, pstate.v.dtype)[:, None])
        acc = pstate.acc.at[:, :, :, :p].set(
            jnp.asarray(acc_rows, jnp.float32)[:, None])
        return PrefillChunkState(k=k, v=v, acc=acc)

    def prefill_chunk(self, params, pstate: PrefillChunkState, tokens_c,
                      row0, length) -> Tuple[jax.Array, PrefillChunkState]:
        """One Sarathi-style prefill slice: run the whole layer stack over
        prompt rows [row0, row0+C), streaming each layer's K/V into the
        workspace. tokens_c: [B,C]; row0: scalar int32 (may be traced —
        one compiled program per (C, bucket) pair, NOT per offset);
        length: [B] true prompt lengths. Returns (final-stack hidden
        [B,C,d] for this chunk, updated workspace)."""
        cfg, prune = self.cfg, self.prune
        b, c = tokens_c.shape
        length = jnp.asarray(length, jnp.int32)
        x = self._embed_tokens(params, tokens_c)
        pos = row0 + jnp.arange(c)[None]
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)
        (kind, _), = [s for s in self._segments() if s[1] > 0]

        def body(x, inp):
            pl, bufs = inp
            return _block_prefill_chunk(pl, x, cfg, prune, bufs, kind,
                                        pos, row0, length)

        x, new_bufs = xscan(body, x, (params[f"seg0_{kind}"], pstate))
        return x, new_bufs

    def prefill_finalize(self, params, pstate: PrefillChunkState, x_last,
                         row0, length) -> Tuple[jax.Array, DecodeState]:
        """Finish a chunked prefill: one-shot static pruning over the
        streamed buffers + last-valid logits. x_last: the final processed
        chunk's hidden [B,C,d] (must contain position length-1); row0 its
        absolute offset. Returns (logits [B,V], DecodeState) — identical
        to what a whole-prompt bucketed `prefill` would have produced."""
        prune = self.prune
        length = jnp.asarray(length, jnp.int32)
        state = self.init_decode_state(x_last.shape[0])

        def fill(cache_l, k_l, v_l, acc_l):
            return kvcache.prefill_fill(cache_l, k_l, v_l, acc_l, prune,
                                        length=length)

        kv = jax.vmap(fill)(state.kv, pstate.k, pstate.v, pstate.acc)
        idx = (length - 1 - row0)[:, None, None]
        x_sel = jnp.take_along_axis(x_last, idx, axis=1)
        logits = self._logits(params, x_sel)[:, 0]
        return logits, state._replace(kv=kv)

    def _prefill_hybrid(self, params, x, pos, state: DecodeState):
        cfg = self.cfg
        period = cfg.attn_period
        n_groups = cfg.num_layers // period

        def group_body(carry, inp):
            x = carry
            gp, kv_g, ssm_g = inp
            x, kv_g2 = _block_prefill(params["shared_attn"], x, cfg, pos,
                                      self.prune, kv_g, "dense")
            def inner(x, inp2):
                pl, st = inp2
                y, st2 = _block_prefill(pl, x, cfg, pos, self.prune, st,
                                        "ssm")
                return y, st2
            x, ssm_g2 = xscan(inner, x, (gp, ssm_g))
            return x, (kv_g2, ssm_g2)

        ssm_main = jax.tree.map(lambda a: a[:n_groups * period]
                                .reshape((n_groups, period) + a.shape[1:]),
                                state.ssm)
        x, (kv_new, ssm_new) = xscan(
            group_body, x, (params["ssm_groups"], state.kv, ssm_main))
        ssm_new = jax.tree.map(
            lambda a: a.reshape((n_groups * period,) + a.shape[2:]), ssm_new)
        if "ssm_tail" in params:
            ssm_tail = jax.tree.map(lambda a: a[n_groups * period:],
                                    state.ssm)
            def inner(x, inp2):
                pl, st = inp2
                y, st2 = _block_prefill(pl, x, cfg, pos, self.prune, st,
                                        "ssm")
                return y, st2
            x, tail_new = xscan(inner, x, (params["ssm_tail"],
                                                  ssm_tail))
            ssm_new = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   ssm_new, tail_new)
        return x, DecodeState(kv=kv_new, ssm=ssm_new, cross=None)

    def _prefill_encdec(self, params, batch):
        cfg = self.cfg
        prune = self.prune
        enc = batch["enc_embed"].astype(_dtype(cfg.compute_dtype))
        pos_e = jnp.arange(enc.shape[1])[None]
        enc = enc + L.sinusoidal(pos_e, cfg.d_model).astype(enc.dtype)
        enc, _ = self._scan_train(params["seg0_encdec_enc"], enc, pos_e,
                                  "encdec_enc", causal=False)
        xkv = jax.vmap(lambda pl: encode_cross_kv(pl["xattn"], enc, cfg)
                       )(params["seg1_encdec_dec"])
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = self._embed_tokens(params, tokens)
        pos = jnp.arange(t)[None]
        x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)
        state = self.init_decode_state(b, cross_len=enc.shape[1])

        def body(x, inp):
            pl, c, ckv = inp
            y, c2 = _block_prefill(pl, x, cfg, pos, prune, c, "encdec_dec",
                                   cross_kv=ckv)
            return y, c2
        x, kv = xscan(body, x, (params["seg1_encdec_dec"], state.kv,
                                       xkv))
        state = DecodeState(kv=kv, ssm=None, cross=xkv)
        return self._logits(params, x[:, -1:])[:, 0], state

    # -- decode ---------------------------------------------------------------

    def supports_inplace_decode(self) -> bool:
        """True when the decode step can run the zero-copy in-place path:
        scanned attention segments whose cache updates are
        scatter/windowed-row writes into the layer-stacked buffers (the
        stacked cache rides the layer scans as a CARRY, so donated
        buffers stay input-output aliased end-to-end). Plain attention
        stacks (dense/moe GQA) and the MLA latent cache
        (`mla_decode_stacked` — mla_moe's two segments scan sequentially
        over one stacked cache); recurrent (ssm/hybrid) and enc-dec
        cross-attention keep the functional path."""
        cfg = self.cfg
        return (cfg.family in ("dense", "moe") and cfg.mla is None) \
            or cfg.family == "mla_moe"

    def decode_step(self, params, state: DecodeState, token: jax.Array,
                    window: Optional[int] = None,
                    active: Optional[jax.Array] = None,
                    inplace: Optional[bool] = None
                    ) -> Tuple[jax.Array, DecodeState]:
        """token: [B] int32 → (logits [B,V], state).

        `window` (STATIC int, optional) runs the whole step — CAM scoring,
        selection, gather, exact attention, charge-domain accumulation,
        and the token write — over the `[:window]` slot prefix of every
        layer's cache. Live slots are always a fill prefix (see
        `core/cache.slot_window`), so a window covering `max(fill) + 1`
        is bit-identical to the full-width step while paying O(window)
        instead of O(slots) per layer. Callers quantize the window
        (`core/cache.decode_window`) to bound the jit cache.

        Families that `supports_inplace_decode()` default to the ZERO-COPY
        path: the stacked cache threads the layer scan as a carry and
        windowed reads / scatter writes keep every buffer input-output
        aliased under `donate_argnums` — no per-step cache copy. `active`
        ([B] bool, optional, in-place path only) freezes finished lanes
        at the write source, replacing the decode block's full-width
        `state_lane_select` merge. `inplace=False` forces the functional
        slice-merge path (the parity oracle in tests); other families
        always use it (where `active` must stay None — callers lane-select
        instead)."""
        if inplace is None:
            inplace = self.supports_inplace_decode()
        if inplace and state.kv is not None:
            assert self.supports_inplace_decode(), self.cfg.family
            return self._decode_step_inplace(params, state, token, window,
                                             active)
        assert active is None, "active-lane gating needs the in-place path"
        if (window is not None and state.kv is not None
                and window < state.kv.k.shape[-2]):
            win = state._replace(kv=kvcache.slot_window(state.kv, window))
            logits, win = self._decode_step_full(params, win, token)
            return logits, win._replace(
                kv=kvcache.slot_window_merge(state.kv, win.kv))
        return self._decode_step_full(params, state, token)

    def _decode_step_inplace(self, params, state: DecodeState,
                             token: jax.Array, window: Optional[int],
                             active: Optional[jax.Array]
                             ) -> Tuple[jax.Array, DecodeState]:
        """One decode step with the stacked cache as the layer scan's
        CARRY: each layer reads a `dynamic_slice` window view and writes
        its token row back by scatter (`core/attention.decode_attention_
        stacked`), so no layer ever materializes a fresh cache buffer —
        the per-step copy floor of the xs/ys functional scan is gone and
        XLA aliases the donated DecodeState straight through. Multi-
        segment families (mla_moe: mla_dense then mla_moe) run one scan
        per segment with a running global layer offset into the same
        stacked carry."""
        cfg = self.cfg
        prune = self.prune
        x = params["embed"][token].astype(_dtype(cfg.compute_dtype))
        if cfg.pos == "sinusoidal" and state.kv is not None:
            pos = state.kv.step[0][:, None]
            x = x + L.sinusoidal(pos, cfg.d_model)[:, 0].astype(x.dtype)
        kv = state.kv
        li0 = 0
        for i, (kind, n) in enumerate(self._segments()):
            if n == 0:
                continue

            def body(carry, inp, kind=kind):
                x, kv = carry
                pl, li = inp
                x, kv = _block_decode_stacked(pl, x, cfg, prune, kv, li,
                                              kind, window, active)
                return (x, kv), None

            (x, kv), _ = xscan(body, (x, kv),
                               (params[f"seg{i}_{kind}"],
                                jnp.arange(li0, li0 + n)))
            li0 += n
        state = state._replace(kv=kv)
        return self._logits(params, x[:, None])[:, 0], state

    def _decode_step_full(self, params, state: DecodeState, token: jax.Array
                          ) -> Tuple[jax.Array, DecodeState]:
        cfg = self.cfg
        prune = self.prune
        x = params["embed"][token].astype(_dtype(cfg.compute_dtype))

        if cfg.family == "encdec":
            pos = state.kv.step[0][:, None]                  # [B,1]
            x = x + L.sinusoidal(pos, cfg.d_model)[:, 0].astype(x.dtype)
            def body(x, inp):
                pl, c, ckv = inp
                y, c2 = _block_decode(pl, x, cfg, prune, c, "encdec_dec",
                                      cross_kv=ckv)
                return y, c2
            x, kv = xscan(body, x, (params["seg1_encdec_dec"],
                                           state.kv, state.cross))
            state = state._replace(kv=kv)
            return self._logits(params, x[:, None])[:, 0], state

        if cfg.pos == "sinusoidal" and state.kv is not None:
            pos = state.kv.step[0][:, None]
            x = x + L.sinusoidal(pos, cfg.d_model)[:, 0].astype(x.dtype)

        if cfg.family == "hybrid":
            x, state = self._decode_hybrid(params, x, state)
        elif cfg.family == "ssm":
            def body(x, inp):
                pl, st = inp
                y, st2 = _block_decode(pl, x, cfg, prune, st, "ssm")
                return y, st2
            x, new_ssm = xscan(body, x, (params["seg0_ssm"],
                                                state.ssm))
            state = state._replace(ssm=new_ssm)
        else:
            li = 0
            new_caches = []
            for i, (kind, n) in enumerate(self._segments()):
                if n == 0:
                    continue
                kv_seg = jax.tree.map(lambda a: a[li:li + n], state.kv)
                def body(x, inp, kind=kind):
                    pl, c = inp
                    y, c2 = _block_decode(pl, x, cfg, prune, c, kind)
                    return y, c2
                x, kv_out = xscan(body, x,
                                         (params[f"seg{i}_{kind}"], kv_seg))
                new_caches.append(kv_out)
                li += n
            kv = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_caches)
            state = state._replace(kv=kv)
        return self._logits(params, x[:, None])[:, 0], state

    def _decode_hybrid(self, params, x, state: DecodeState):
        cfg = self.cfg
        period = cfg.attn_period
        n_groups = cfg.num_layers // period

        def group_body(x, inp):
            gp, kv_g, ssm_g = inp
            x, kv_g2 = _block_decode(params["shared_attn"], x, cfg,
                                     self.prune, kv_g, "dense")
            def inner(x, inp2):
                pl, st = inp2
                y, st2 = _block_decode(pl, x, cfg, self.prune, st, "ssm")
                return y, st2
            x, ssm_g2 = xscan(inner, x, (gp, ssm_g))
            return x, (kv_g2, ssm_g2)

        ssm_main = jax.tree.map(lambda a: a[:n_groups * period]
                                .reshape((n_groups, period) + a.shape[1:]),
                                state.ssm)
        x, (kv_new, ssm_new) = xscan(
            group_body, x, (params["ssm_groups"], state.kv, ssm_main))
        ssm_new = jax.tree.map(
            lambda a: a.reshape((n_groups * period,) + a.shape[2:]), ssm_new)
        if "ssm_tail" in params:
            ssm_tail = jax.tree.map(lambda a: a[n_groups * period:],
                                    state.ssm)
            def inner(x, inp2):
                pl, st = inp2
                y, st2 = _block_decode(pl, x, cfg, self.prune, st, "ssm")
                return y, st2
            x, tail_new = xscan(inner, x, (params["ssm_tail"],
                                                  ssm_tail))
            ssm_new = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   ssm_new, tail_new)
        return x, DecodeState(kv=kv_new, ssm=ssm_new, cross=None)
