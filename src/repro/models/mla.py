"""DeepSeek-V3 Multi-head Latent Attention (MLA) with UniCAIM pruning in
LATENT space — a beyond-paper extension (DESIGN.md §5).

The decode cache holds the compressed per-token latent u = [c_kv ⊕ k_rope]
(kv_lora + rope dims). Scores are computed with the absorbed query
q_abs = [W_ukᵀ q_nope ⊕ q_rope], so both the CAM-mode approximate pass and
the exact pass run directly on the latent mirror:

    q·k  ==  q_nope·(W_uk c) + q_rope·k_rope  ==  q_abs·u

Values are never materialised per token: attention contracts probabilities
against the latents and up-projects once (ctx @ W_uv).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import quant, scoring, topk
from repro.core.cache import (KVCache, _token_writes, layer_window,
                              prefill_fill, protected_mask, write_token,
                              write_token_stacked)
from repro.core.topk import NEG_INF
from repro.models.layers import dense_init, rope
from repro.runtime.sharding import shard


def init_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank,
                           h * (m.qk_nope_dim + m.qk_rope_dim), dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model,
                            m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_dim + m.v_dim), dtype),
        "wo": dense_init(ks[4], h * m.v_dim, cfg.d_model, dtype),
    }


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def _split_wkv_b(p, cfg: ModelConfig):
    m = cfg.mla
    w = p["wkv_b"].reshape(m.kv_lora_rank, cfg.n_heads,
                           m.qk_nope_dim + m.v_dim)
    return w[..., :m.qk_nope_dim], w[..., m.qk_nope_dim:]   # W_uk, W_uv


def _queries(p, x, cfg: ModelConfig, positions):
    """x [B,T,d] → q_nope [B,T,H,nope], q_rope [B,T,H,rope] (RoPE'd)."""
    m = cfg.mla
    b, t, _ = x.shape
    cq = _rms(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, t, cfg.n_heads,
                                 m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg: ModelConfig, positions):
    """x [B,T,d] → u [B,T,kv_lora+rope] (c_kv normed, k_rope RoPE'd)."""
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv = _rms(kv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = rope(kv[..., m.kv_lora_rank:][..., None, :], positions,
                  cfg.rope_theta)[..., 0, :]
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def _mla_attend(p, x, cfg: ModelConfig, positions, chunk: int,
                obs_window: int = 0, length=None):
    """Absorbed-form chunked causal MLA.

    Returns (out [B,T,d], u [B,T,latent], acc [B,1,T]). Never materialises
    the T×T matrix or per-head K/V: scores and context both contract against
    the shared latent (one "kv head"), then a single per-head up-projection.
    """
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(p, x, cfg, positions)
    u = _latents(p, x, cfg, positions)
    w_uk, w_uv = _split_wkv_b(p, cfg)
    q_abs = jnp.einsum("bthn,khn->bthk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))            # [B,T,H,kv_lora]
    q_full = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)], -1)
    q_full = shard(q_full.transpose(0, 2, 1, 3), "batch", "heads", "seq",
                   None)                                    # [B,H,T,latent]
    scale = 1.0 / jnp.sqrt(float(m.qk_nope_dim + m.qk_rope_dim))
    from repro.core.attention import chunked_causal_attention
    ctx, acc = chunked_causal_attention(
        q_full.astype(jnp.float32), u[:, None],             # Hk = 1
        u[:, None, :, :m.kv_lora_rank], chunk=min(chunk, t), scale=scale,
        obs_window=obs_window, length=length)               # ctx [B,H,T,kvr]
    out = jnp.einsum("bhtk,khv->bthv", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, t, h * m.v_dim).astype(x.dtype)
    return out @ p["wo"], u, acc


def mla_train(p, x, cfg: ModelConfig, positions, chunk: int = 0):
    """Chunked causal MLA for training. [B,T,d]→[B,T,d]."""
    out, _, _ = _mla_attend(p, x, cfg, positions, chunk or cfg.attn_chunk)
    return out


def mla_prefill(p, x, cfg: ModelConfig, positions, prune: PruneConfig,
                cache: KVCache, chunk: int = 0, length=None):
    """Prefill with one-shot static pruning of the LATENT cache.

    `length` ([B] int32, optional): true per-lane lengths for bucketed
    (right-padded) prompts."""
    out, u, acc = _mla_attend(p, x, cfg, positions, chunk or cfg.attn_chunk,
                              obs_window=prune.prefill_obs_window,
                              length=length)
    cache = prefill_fill(cache, u[:, None, :, :], None, acc, prune,
                         length=length)
    return out, cache


def _mla_blocked_shardmap(cache: KVCache, q_full: jax.Array,
                          biased: jax.Array, prune: PruneConfig, mesh,
                          kv_lora: int, scale_dim: int) -> jax.Array:
    """Shard-local latent selection for MLA decode (distributed CAM race
    over the latent mirror). Returns ctx [B, H, kv_lora]."""
    from repro.runtime.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.attention import _slot_axes

    b, h, lat = q_full.shape
    nb = prune.select_blocks
    k_loc = prune.select_k // nb
    slot_axes = _slot_axes(mesh, nb)
    red = slot_axes if len(slot_axes) > 1 else slot_axes[0]
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.shape and a not in slot_axes
                       and b % mesh.shape[a] == 0)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    sspec = slot_axes if len(slot_axes) > 1 else slot_axes[0]
    quantized = cache.quantized_kv

    def local_fn(q_l, u_l, ks_l, valid_l, sc_l):
        _, idx = jax.lax.top_k(sc_l, k_loc)                # [b,1,k_loc]
        u_sel = jnp.take_along_axis(u_l, idx[..., None], axis=2)[:, 0]
        if quantized:
            us = jnp.take_along_axis(ks_l, idx, axis=2)[:, 0]
            u_sel = u_sel.astype(jnp.float32) * us[..., None]
        valid_sel = jnp.take_along_axis(valid_l, idx, axis=2)[:, 0]
        logits = jnp.einsum("bhl,bkl->bhk", q_l.astype(jnp.float32),
                            u_sel.astype(jnp.float32))
        logits = logits / jnp.sqrt(jnp.float32(scale_dim))
        logits = jnp.where(valid_sel[:, None, :], logits, NEG_INF)
        mx = jax.lax.pmax(jnp.max(logits, -1, keepdims=True), red)
        e = jnp.exp(logits - mx) * (logits > NEG_INF / 2)
        z = jax.lax.psum(jnp.sum(e, axis=-1), red)         # [b,H]
        ctx = jnp.einsum("bhk,bkl->bhl", e,
                         u_sel[..., :kv_lora].astype(jnp.float32))
        ctx = jax.lax.psum(ctx, red)
        return ctx / jnp.maximum(z, 1e-30)[..., None]

    scalar = P()
    ks_in = cache.kscale if quantized else jnp.zeros((), jnp.float32)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None),
                  P(bspec, None, sspec, None),
                  P(bspec, None, sspec) if quantized else scalar,
                  P(bspec, None, sspec),
                  P(bspec, None, sspec)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(q_full.astype(jnp.float32), cache.k, ks_in, cache.valid, biased)


def mla_decode(p, x, cfg: ModelConfig, cache: KVCache, prune: PruneConfig
               ) -> Tuple[jax.Array, KVCache]:
    """One decode step with UniCAIM selection in latent space.

    x: [B,d] → (y [B,d], cache). Cache holds latents (Hk=1, v=None).
    """
    m = cfg.mla
    b, _ = x.shape
    h = cfg.n_heads
    pos = cache.step[:, None]                               # [B,1]
    q_nope, q_rope = _queries(p, x[:, None, :], cfg, pos)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]             # [B,H,*]
    u_new = _latents(p, x[:, None, :], cfg, pos)[:, 0]      # [B,latent]
    cache = write_token(cache, u_new[:, None, :], None, prune)

    w_uk, w_uv = _split_wkv_b(p, cfg)
    q_abs = jnp.einsum("bhn,khn->bhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_full = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)], -1)
    ctx, cache = _latent_attend(cache, q_full, cfg, prune)
    out = jnp.einsum("bhk,khv->bhv", ctx, w_uv.astype(jnp.float32))
    y = out.reshape(b, h * m.v_dim).astype(x.dtype) @ p["wo"]
    return y, cache


def mla_decode_stacked(p, x, cfg: ModelConfig, kv: KVCache, li,
                       prune: PruneConfig, window, active
                       ) -> Tuple[jax.Array, KVCache]:
    """One IN-PLACE decode step at layer `li` of a layer-stacked LATENT
    cache — the MLA twin of `core.attention.decode_attention_stacked`.

    Same zero-copy split: reads go through a `dynamic_slice` window view
    of layer `li` (`layer_window`), the token write mirrors into the view
    for the attend and then lands in the full-width stacked buffers as
    O(B·latent) scatters (Hk = 1) plus one O(window) accumulator-row
    update, with the zero-valued `dep` index trick pinning the schedule
    so XLA keeps the scan carry aliased (see decode_attention_stacked for
    why that is load-bearing). `active` freezes finished lanes at the
    source exactly as in the GQA path. x: [B,d] post-norm hidden.
    Returns (y [B,d], stacked cache)."""
    m = cfg.mla
    b, _ = x.shape
    h = cfg.n_heads
    w = kv.slots if window is None or window >= kv.slots else window
    view = layer_window(kv, li, w)
    pos = view.step[:, None]                                # [B,1]
    q_nope, q_rope = _queries(p, x[:, None, :], cfg, pos)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]             # [B,H,*]
    u_new = _latents(p, x[:, None, :], cfg, pos)[:, 0]      # [B,latent]
    slot, vals = _token_writes(view, u_new[:, None, :], None, prune)
    # mirror the token write into the view (all lanes, matching the
    # functional path — inactive lanes' results never land anywhere)
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(1)[None, :]
    acc0 = view.acc
    view = view._replace(
        **{f: getattr(view, f).at[bi, hi, slot].set(v)
           for f, v in vals.items()},
        fill=jnp.minimum(view.fill + 1, w), step=view.step + 1)

    w_uk, w_uv = _split_wkv_b(p, cfg)
    q_abs = jnp.einsum("bhn,khn->bhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_full = jnp.concatenate([q_abs, q_rope.astype(jnp.float32)], -1)
    ctx, view = _latent_attend(view, q_full, cfg, prune)
    acc_row = view.acc
    if active is not None:
        acc_row = jnp.where(active[:, None, None], acc_row, acc0)
    out = jnp.einsum("bhk,khv->bhv", ctx, w_uv.astype(jnp.float32))
    y = out.reshape(b, h * m.v_dim).astype(x.dtype) @ p["wo"]
    # storage writes LAST, index-dependent on the attend output (ctx
    # covers every latent-buffer read, acc_row the accumulator reads —
    # see decode_attention_stacked for why this pin is load-bearing)
    dep = jnp.nan_to_num(0.0 * (jnp.sum(ctx) + jnp.sum(acc_row))
                         ).astype(jnp.int32)
    kv = write_token_stacked(kv, li, slot + dep,
                             {f: v for f, v in vals.items() if f != "acc"},
                             active)
    li = jnp.asarray(li, jnp.int32) + dep
    acc = jax.lax.dynamic_update_slice(kv.acc, acc_row[None],
                                       (li, 0, 0, 0))
    return y, kv._replace(acc=acc)


def _latent_attend(cache: KVCache, q_full: jax.Array, cfg: ModelConfig,
                   prune: PruneConfig) -> Tuple[jax.Array, KVCache]:
    """Policy attend over a latent cache that already holds the new token.

    q_full: [B,H,latent] absorbed query. Returns (ctx [B,H,kv_lora],
    cache with the charge-domain accumulator updated). Shared verbatim by
    the functional `mla_decode` and the in-place `mla_decode_stacked`
    (which hands it a windowed read VIEW of the stacked cache), so both
    paths are the same arithmetic — the basis of their bitwise parity."""
    m = cfg.mla
    scale_dim = m.qk_nope_dim + m.qk_rope_dim

    if prune.policy == "unicaim":
        qq, qs = quant.quantize_query(q_full, prune.query_bits)
        mirror = cache.kq if cache.kq is not None else cache.k
        s_apx = scoring.approx_scores(qq, qs, mirror, cache.kscale,
                                      cache.valid)          # [B,H,S]
        grouped = topk.gqa_group_scores(s_apx, 1)           # [B,1,S]
        biased = topk.apply_selection_bias(
            grouped, protected_mask(cache, prune), ~cache.valid)
        from repro.core.attention import _slot_axes
        from repro.runtime.sharding import active_mesh
        mesh = active_mesh()
        if (prune.select_blocks > 1 and mesh is not None
                and _slot_axes(mesh, prune.select_blocks)):
            ctx = _mla_blocked_shardmap(cache, q_full, biased, prune,
                                        mesh, m.kv_lora_rank, scale_dim)
        else:
            _, idx = topk.exact_topk(biased, prune.select_k)  # [B,1,k]
            u_sel = jnp.take_along_axis(cache.k, idx[..., None],
                                        axis=2)[:, 0]
            if cache.quantized_kv:
                u_scale = jnp.take_along_axis(cache.kscale, idx,
                                              axis=2)[:, 0]
                u_sel = u_sel.astype(jnp.float32) * u_scale[..., None]
            valid_sel = jnp.take_along_axis(cache.valid, idx, axis=2)[:, 0]
            logits = jnp.einsum("bhk,bsk->bhs", q_full,
                                u_sel.astype(jnp.float32)) / jnp.sqrt(
                                    float(scale_dim))
            logits = jnp.where(valid_sel[:, None, :], logits, NEG_INF)
            pr = jax.nn.softmax(logits, axis=-1)            # [B,H,k]
            ctx = jnp.einsum("bhs,bsk->bhk", pr,
                             u_sel[..., :m.kv_lora_rank]
                             .astype(jnp.float32))
        probs_acc = scoring.score_probs(s_apx, scale_dim)
        acc = scoring.accumulate(cache.acc, probs_acc, 1, prune.acc_decay)
        cache = cache._replace(acc=acc)
    else:  # dense / h2o / streaming over the latent cache
        u_all = cache.k_values()[:, 0].astype(jnp.float32)
        logits = jnp.einsum("bhk,bsk->bhs", q_full, u_all) / jnp.sqrt(
            float(scale_dim))
        logits = jnp.where(cache.valid[:, 0][:, None, :], logits, NEG_INF)
        pr = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhs,bsk->bhk", pr,
                         u_all[:, :, :m.kv_lora_rank])
        if prune.policy == "h2o":
            acc = scoring.accumulate(cache.acc, pr, 1, prune.acc_decay)
            cache = cache._replace(acc=acc)
    return ctx, cache
