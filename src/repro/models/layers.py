"""Shared neural layers: norms, RoPE, MLPs, embeddings.

Parameters are nested dicts of jnp arrays; init functions are pure (usable
under jax.eval_shape for the allocation-free dry-run). Activations carry
logical sharding hints via runtime.sharding.shard().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.float32(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "rms":
        return {"w": jnp.ones((cfg.d_model,), dtype)}
    return {"w": jnp.ones((cfg.d_model,), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype)}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["w"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


def rms_norm_gated(x, z, w, eps: float = 1e-6):
    """Mamba2 gated RMSNorm: norm(x * silu(z)) * w."""
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / sinusoidal positions
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., T, H, dh] (or [..., H, dh] with positions
    [...]); rotates pairs (even, odd) of the last axis."""
    dh = x.shape[-1]
    half = dh // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq       # [..., half]
    # broadcast over head axis: positions [..., T] → [..., T, 1, half]
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wo": dense_init(k2, d_ff, cfg.d_model, dtype)}
    if cfg.act == "swiglu":
        p["wi"] = dense_init(k1, cfg.d_model, d_ff, dtype)
        p["wg"] = dense_init(k3, cfg.d_model, d_ff, dtype)
    else:
        p["wi"] = dense_init(k1, cfg.d_model, d_ff, dtype)
    return p


def apply_mlp(p, x, act: str):
    h = x @ p["wi"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(act)
    h = shard(h, "batch", "seq", "ff")
    return h @ p["wo"]
