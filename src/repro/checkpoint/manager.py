"""Checkpoint manager: sharded-pytree save/restore with atomic commit,
retention, and async save — the restart substrate for fault tolerance.

Format: one directory per step containing
  * tree.json     — pytree structure + leaf metadata (shape/dtype/path)
  * arrays.npz    — leaf buffers (process-local shards on a real fleet;
                    single-process here, but the layout is per-leaf so a
                    multi-host writer only changes the gather step)
A checkpoint is COMMITTED by the atomic rename tmp→final; partial writes
are never visible, so a crash mid-save cannot corrupt the restore path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, block: bool = False):
        """Snapshot to host memory synchronously, write asynchronously."""
        paths, leaves, _ = _flatten_with_paths(state)
        host = [np.asarray(l) for l in leaves]   # device→host copy (sync)
        dtypes = [str(l.dtype) for l in leaves]
        if self._pending is not None:
            self._pending.join()
        t = threading.Thread(target=self._write, args=(step, paths, host,
                                                       dtypes))
        t.start()
        self._pending = t
        if block or not self.async_save:
            t.join()

    def _write(self, step: int, paths, host, dtypes):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": h for i, h in enumerate(host)})
        meta = {"step": step, "time": time.time(),
                "leaves": [{"path": p, "dtype": d}
                           for p, d in zip(paths, dtypes)]}
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                   # atomic commit
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `like` (ShapeDtypeStructs fine).
        `shardings` (optional pytree of NamedSharding) enables elastic
        restore onto a different mesh than the one that saved."""
        final = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(final, "tree.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(final, "arrays.npz"))
        arrays = [data[f"a{i}"] for i in range(len(meta["leaves"]))]
        paths, leaves, treedef = _flatten_with_paths(like)
        assert len(arrays) == len(leaves), \
            f"checkpoint has {len(arrays)} leaves, target {len(leaves)}"
        sh_leaves = (treedef.flatten_up_to(shardings) if shardings is not None
                     else [None] * len(leaves))
        out = []
        for arr, leaf, sh in zip(arrays, leaves, sh_leaves):
            a = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
            out.append(jax.device_put(a, sh) if sh is not None
                       else jnp.asarray(a))
        return treedef.unflatten(out)

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Optional[Any]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like, shardings)
