"""Pallas TPU kernel — current-domain exact attention over gathered top-k
slots (UniCAIM §III-B.5).

After dynamic selection, only k ≪ S rows of K/V are touched. The XLA gather
lands them contiguously; this kernel then runs the exact softmax·V entirely
in VMEM with a flash-style online softmax over k blocks, so arbitrary
select_k values stream without spilling.

  q     [BH, G, d]    query group (one decode step)
  k     [BH, K, d]    gathered keys
  v     [BH, K, dv]   gathered values
  valid [BH, K]       int8 mask (gathered slot validity)
  out   [BH, G, dv]   f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _gather_attn_kernel(q_ref, k_ref, v_ref, valid_ref, out_ref,
                        m_ref, l_ref, o_ref, *, scale, nkb):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)                      # [G, d]
    k = k_ref[0].astype(jnp.float32)                      # [Bk, d]
    v = v_ref[0].astype(jnp.float32)                      # [Bk, dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid_ref[0][None, :] != 0, s, NEG_INF)

    m_prev = m_ref[...]                                   # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                # [G, Bk]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _flush():
        out_ref[0] = o_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def gather_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    bh, g, d = q.shape
    _, kk, dv = v.shape
    block_k = min(block_k, kk)
    assert kk % block_k == 0, f"k {kk} % block {block_k} != 0"
    nkb = kk // block_k
    kernel = functools.partial(_gather_attn_kernel,
                               scale=1.0 / (d ** 0.5), nkb=nkb)
    return pl.pallas_call(
        kernel,
        grid=(bh, nkb),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, g, dv), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int8))
