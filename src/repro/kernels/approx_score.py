"""Pallas TPU kernel — CAM-mode approximate scoring (UniCAIM §III-B.3).

Computes quantized attention scores for ALL cache slots from the int8 key
mirror. This is the kernel that realises the paper's "O(1) associative
search" as a bandwidth statement on TPU: it reads `S·d` int8 bytes (the
mirror) instead of `S·d·2` bf16 bytes, and runs the contraction on the MXU.

Layout (heads collapsed): one grid cell scores one kv-head's slot block
against its whole GQA query group.

  qq     [BH, G, d]   int8   quantized queries (group of G q-heads)
  qscale [BH, G]      f32
  kq     [BH, S, d]   int8   quantized key mirror
  kscale [BH, S]      f32
  valid  [BH, S]      bool (passed as int8 mask)
  out    [BH, G, S]   f32    scores; NEG_INF at invalid slots

Block over S (block_s slots per grid step); d and G live fully in VMEM:
VMEM per step ≈ block_s·d (int8) + G·d + 2·block_s·4 ≈ 64KB @ (512, 128).
MXU alignment: d is a multiple of 128 for every assigned arch; G is padded
to the sublane count by Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _approx_score_kernel(qq_ref, qs_ref, kq_ref, ks_ref, valid_ref, out_ref):
    q = qq_ref[0].astype(jnp.float32)                      # [G, d]
    k = kq_ref[0].astype(jnp.float32)                      # [Bs, d]
    raw = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [G, Bs]
    sc = raw * qs_ref[0][:, None] * ks_ref[0][None, :]
    ok = valid_ref[0][None, :] != 0
    out_ref[0] = jnp.where(ok, sc, NEG_INF)


def _approx_score_packed_kernel(qq_ref, qs_ref, kq_ref, ks_ref, valid_ref,
                                out_ref):
    """Packed-nibble variant: unpacks two 4-bit signed codes per byte in
    VMEM — the mirror read from HBM is d/2 bytes per slot (the paper's
    multilevel-cell density made real on TPU)."""
    q = qq_ref[0].astype(jnp.float32)                      # [G, d]
    packed = kq_ref[0]                                     # [Bs, d//2] uint8
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    k = jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[0], packed.shape[1] * 2).astype(jnp.float32)
    raw = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [G, Bs]
    sc = raw * qs_ref[0][:, None] * ks_ref[0][None, :]
    ok = valid_ref[0][None, :] != 0
    out_ref[0] = jnp.where(ok, sc, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def approx_score_packed(qq: jax.Array, qscale: jax.Array, kq_packed: jax.Array,
                        kscale: jax.Array, valid: jax.Array,
                        block_s: int = 512, interpret: bool = False
                        ) -> jax.Array:
    """CAM scoring over an int4-PACKED mirror. kq_packed: [BH, S, d//2]."""
    bh, g, d = qq.shape
    _, s, half = kq_packed.shape
    assert half * 2 == d
    block_s = min(block_s, s)
    assert s % block_s == 0
    grid = (bh, s // block_s)
    return pl.pallas_call(
        _approx_score_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, g), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_s, half), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, g, block_s), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bh, g, s), jnp.float32),
        interpret=interpret,
    )(qq, qscale.astype(jnp.float32), kq_packed,
      kscale.astype(jnp.float32), valid.astype(jnp.int8))


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def approx_score(qq: jax.Array, qscale: jax.Array, kq: jax.Array,
                 kscale: jax.Array, valid: jax.Array,
                 block_s: int = 512, interpret: bool = False) -> jax.Array:
    bh, g, d = qq.shape
    _, s, _ = kq.shape
    block_s = min(block_s, s)
    assert s % block_s == 0, f"slots {s} % block {block_s} != 0"
    grid = (bh, s // block_s)
    return pl.pallas_call(
        _approx_score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, g), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, g, block_s), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bh, g, s), jnp.float32),
        interpret=interpret,
    )(qq, qscale.astype(jnp.float32), kq, kscale.astype(jnp.float32),
      valid.astype(jnp.int8))
