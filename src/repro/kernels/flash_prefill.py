"""Pallas TPU kernel — prefill flash attention WITH accumulated column
scores (UniCAIM §III-A.1 statistics harvested in-kernel).

Standard causal flash attention forward, plus a second sweep over the key
blocks that re-materialises the (now exactly normalised) probabilities and
accumulates their column sums — the statistic the one-shot static pruning
ranks tokens by. The second sweep doubles the score matmuls but keeps the
whole statistic on-chip: no [N, N] matrix, no extra HBM round-trip (the
XLA fallback pays that round-trip; see EXPERIMENTS.md §Perf).

  q   [BH, N, d]  per-q-head queries (BH = B·Hq)
  k   [BK, N, d]  per-kv-head keys   (BK = B·Hk; index map shares a kv head
  v   [BK, N, d]   across its GQA group, no expansion copy)
  len [BH, 1] i32 true row length (bucketed prefill: N is a shape bucket,
                  rows >= len are right-padding and add no column mass)
  out [BH, N, d]  attention output (garbage at pad rows — caller slices)
  acc [BH, N] f32 column sums of attention probabilities (group-sum outside)

Grid: (BH, Q_blocks, 2·K_blocks) — kb < K_blocks: flash pass;
kb >= K_blocks: column-accumulation pass using the finalised (m, l).
Pad *columns* never receive mass from real rows via the causal mask (pads
sit at the end); pad *rows* are excluded from the accumulation pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_prefill_kernel(q_ref, k_ref, v_ref, len_ref, out_ref, acc_ref,
                          m_ref, l_ref, o_ref, col_ref,
                          *, scale, block_q, block_k, nkb, nqb, n):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    kk = jax.lax.rem(kb, nkb)
    phase2 = kb >= nkb

    @pl.when((qb == 0) & (kb == 0))
    def _zero_cols():
        col_ref[...] = jnp.zeros_like(col_ref)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    row0 = qb * block_q
    col0 = kk * block_k
    # causal: the whole block is masked iff its first column exceeds the
    # last row — skip both passes there.
    live = col0 <= row0 + block_q - 1

    @pl.when(live)
    def _work():
        q = q_ref[0].astype(jnp.float32)                   # [Tq, d]
        k = k_ref[0].astype(jnp.float32)                   # [Tk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

        @pl.when(~phase2)
        def _flash():
            v = v_ref[0].astype(jnp.float32)               # [Tk, d]
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
            o_ref[...] = o_ref[...] * corr + jax.lax.dot(
                p, v, preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(phase2)
        def _cols():
            # exact normalised probabilities with the finalised stats;
            # right-padded query rows contribute no column mass
            p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
            p = p * (rows < len_ref[0, 0]).astype(p.dtype)
            colsum = jnp.sum(p, axis=0)                    # [Tk]
            cur = col_ref[0, pl.ds(col0, block_k)]
            col_ref[0, pl.ds(col0, block_k)] = cur + colsum

    @pl.when(kb == nkb - 1)
    def _flush_out():
        out_ref[0] = (o_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)

    @pl.when((qb == nqb - 1) & (kb == 2 * nkb - 1))
    def _flush_acc():
        acc_ref[0] = col_ref[0]


@functools.partial(jax.jit,
                   static_argnames=("group", "block_q", "block_k",
                                    "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, group: int = 1,
                  block_q: int = 256, block_k: int = 256,
                  interpret: bool = False, lengths=None):
    """Returns (out [BH,N,d], acc [BH,N] f32). k/v have BH//group rows.

    `lengths` ([BH] int32, optional): true row counts for bucketed
    (right-padded) prompts — pad rows are excluded from the column sums;
    their output rows are garbage and must be sliced off by the caller."""
    bh, n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    nqb, nkb = n // block_q, n // block_k
    if lengths is None:
        lengths = jnp.full((bh, 1), n, jnp.int32)
    else:
        lengths = lengths.astype(jnp.int32).reshape(bh, 1)
    kernel = functools.partial(
        _flash_prefill_kernel, scale=1.0 / (d ** 0.5),
        block_q=block_q, block_k=block_k, nkb=nkb, nqb=nqb, n=n)
    g = group
    return pl.pallas_call(
        kernel,
        grid=(bh, nqb, 2 * nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, qb, kb: (i, qb, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda i, qb, kb: (i // g, jax.lax.rem(kb, nkb), 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda i, qb, kb: (i // g, jax.lax.rem(kb, nkb), 0)),
            pl.BlockSpec((1, 1), lambda i, qb, kb: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, qb, kb: (i, qb, 0)),
            pl.BlockSpec((1, n), lambda i, qb, kb: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), q.dtype),
            jax.ShapeDtypeStruct((bh, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((1, n), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
