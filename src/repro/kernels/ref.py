"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def approx_score_ref(qq, qscale, kq, kscale, valid):
    """[BH,G,d] int8, [BH,G], [BH,S,d] int8, [BH,S], [BH,S] → [BH,G,S]."""
    raw = jnp.einsum("bgd,bsd->bgs", qq.astype(jnp.int32),
                     kq.astype(jnp.int32)).astype(jnp.float32)
    sc = raw * qscale.astype(jnp.float32)[..., None] \
             * kscale.astype(jnp.float32)[:, None, :]
    return jnp.where(valid[:, None, :] != 0, sc, NEG_INF)


def gather_attention_ref(q, k, v, valid):
    """[BH,G,d], [BH,K,d], [BH,K,dv], [BH,K] → [BH,G,dv] f32."""
    d = q.shape[-1]
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    s = jnp.where(valid[:, None, :] != 0, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32))


def flash_prefill_ref(q, k, v, group=1, lengths=None):
    """[BH,N,d], [BK,N,d], [BK,N,d] → (out [BH,N,d], acc [BH,N] f32).

    `lengths` ([BH] int32, optional): true row counts for right-padded
    prompts — pad rows add no column mass (their output rows are garbage)."""
    bh, n, d = q.shape
    kx = jnp.repeat(k, group, axis=0)
    vx = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / jnp.sqrt(float(d))
    mask = jnp.tril(jnp.ones((n, n), bool))
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, vx.astype(jnp.float32))
    if lengths is not None:
        live = jnp.arange(n)[None, :] < lengths.astype(jnp.int32)[:, None]
        p = p * live[:, :, None]
    return out.astype(q.dtype), jnp.sum(p, axis=1)


def approx_score_packed_ref(qq, qscale, kq_packed, kscale, valid):
    """Oracle for the packed-nibble kernel: unpack then score."""
    from repro.core.quant import unpack_int4
    return approx_score_ref(qq, qscale, unpack_int4(kq_packed), kscale,
                            valid)


def fused_decode_ref(q, qq, qscale, mirror, mscale, kscale, vscale, valid,
                     prot, k, v, *, select_k, num_blocks=1):
    """Oracle for the fused single-pass pruned-decode kernel.

    Shapes as in kernels/fused_decode.py. One fused XLA region: score the
    int8 mirror, block-local top-k, gather ONLY the winners (XLA gather
    reads k rows, not S), exact softmax attention, and the per-slot
    approximate probabilities. Returns (out [BH,G,dv], probs [BH,S]).

    With num_blocks == 1 this is ALSO the oracle for the ragged kernel
    (kernels/ragged_decode.py): slots at/beyond a lane's fill are invalid
    here — NEG_INF-scored, masked out of the attention, zero probability
    — so masking (this path) and skipping (the ragged kernel's dead-block
    early exit) agree to the bit on every live value.
    """
    bh, g, d = q.shape
    s = mirror.shape[1]
    nb = num_blocks
    assert s % nb == 0 and select_k % nb == 0, (s, select_k, nb)
    k_loc = select_k // nb
    scale = 1.0 / jnp.sqrt(float(d))

    raw = jnp.einsum("bgd,bsd->bgs", qq.astype(jnp.float32),
                     mirror.astype(jnp.float32))
    raw = raw * qscale.astype(jnp.float32)[..., None] \
              * mscale.astype(jnp.float32)[:, None, :]
    raw = jnp.where(valid[:, None, :] != 0, raw, NEG_INF)     # [BH,G,S]

    ssel = jnp.sum(raw, axis=1)                               # [BH,S]
    ssel = jnp.where(prot != 0, 1e30, ssel)
    _, idx = jax.lax.top_k(ssel.reshape(bh, nb, s // nb), k_loc)
    gidx = (idx + (jnp.arange(nb) * (s // nb))[None, :, None]
            ).reshape(bh, nb * k_loc)                         # [BH,K]

    k_sel = jnp.take_along_axis(k, gidx[..., None], axis=1).astype(
        jnp.float32) * jnp.take_along_axis(
            kscale.astype(jnp.float32), gidx, axis=1)[..., None]
    v_sel = jnp.take_along_axis(v, gidx[..., None], axis=1).astype(
        jnp.float32) * jnp.take_along_axis(
            vscale.astype(jnp.float32), gidx, axis=1)[..., None]
    valid_sel = jnp.take_along_axis(valid, gidx, axis=1)      # [BH,K]

    logits = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                        k_sel) * scale
    logits = jnp.where(valid_sel[:, None, :] != 0, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (logits > NEG_INF / 2)
    z = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgk,bkd->bgd", e / z, v_sel)

    lg = raw * scale
    eg = jnp.exp(lg - jnp.max(lg, axis=-1, keepdims=True))
    eg = eg * (raw > NEG_INF / 2)
    zg = jnp.maximum(jnp.sum(eg, axis=-1, keepdims=True), 1e-30)
    probs = jnp.sum(eg / zg, axis=1)                          # [BH,S]
    return out, probs
