"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def approx_score_ref(qq, qscale, kq, kscale, valid):
    """[BH,G,d] int8, [BH,G], [BH,S,d] int8, [BH,S], [BH,S] → [BH,G,S]."""
    raw = jnp.einsum("bgd,bsd->bgs", qq.astype(jnp.int32),
                     kq.astype(jnp.int32)).astype(jnp.float32)
    sc = raw * qscale.astype(jnp.float32)[..., None] \
             * kscale.astype(jnp.float32)[:, None, :]
    return jnp.where(valid[:, None, :] != 0, sc, NEG_INF)


def gather_attention_ref(q, k, v, valid):
    """[BH,G,d], [BH,K,d], [BH,K,dv], [BH,K] → [BH,G,dv] f32."""
    d = q.shape[-1]
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    s = jnp.where(valid[:, None, :] != 0, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32))


def flash_prefill_ref(q, k, v, group=1):
    """[BH,N,d], [BK,N,d], [BK,N,d] → (out [BH,N,d], acc [BH,N] f32)."""
    bh, n, d = q.shape
    kx = jnp.repeat(k, group, axis=0)
    vx = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / jnp.sqrt(float(d))
    mask = jnp.tril(jnp.ones((n, n), bool))
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype), jnp.sum(p, axis=1)


def approx_score_packed_ref(qq, qscale, kq_packed, kscale, valid):
    """Oracle for the packed-nibble kernel: unpack then score."""
    from repro.core.quant import unpack_int4
    return approx_score_ref(qq, qscale, unpack_int4(kq_packed), kscale,
                            valid)
