"""Pallas TPU kernel — ragged fused decode: per-lane, not max-lane, cost.

The fused single-pass engine (kernels/fused_decode.py) prices every lane at
the allocated slot count: its grid walks all S/bs mirror blocks per
(batch·kv-head) row even when a lane has filled 128 of 4096 slots. This
kernel is the fill-aware variant — the software analogue of the paper's
O(1) per-array CAM race for a *mixed* batch:

  * the per-row live-block count ``ceil(fill / bs)`` is SCALAR-PREFETCHED,
    so it is available to the index maps before the kernel body runs;
  * dead k-blocks (block index >= live count) remap their mirror DMA to
    the last live block — Pallas elides the copy when consecutive grid
    steps fetch the same block, so a dead block moves no mirror bytes;
  * ``pl.when`` skips the dead block's scoring entirely — a short lane
    pays O(fill) compute + bandwidth while a long lane in the same batch
    pays its own O(fill), instead of everyone paying O(max over lanes).

Selection is GLOBAL top-k (the ``num_blocks == 1`` semantics of the fused
kernel / ``ref.fused_decode_ref``): scores accumulate into a VMEM buffer
initialised to NEG_INF — dead regions therefore rank exactly like invalid
slots — and the last grid step runs the race, DMAs only the winners' K/V
rows from HBM, and emits the exact attention output plus the per-slot
charge-domain probabilities.

  fills  [BH]        int32           live slot count per row (lane fill)
  q      [BH, G, d]  storage dtype   exact queries
  qq     [BH, G, d]  int8            quantized queries (CAM drive lines)
  qscale [BH, G]     f32
  mirror [BH, S, d]  int8            key mirror (int8-KV mode: K itself)
  mscale [BH, S]     f32
  kscale [BH, S]     f32             K-row dequant scale (ones for bf16)
  vscale [BH, S]     f32
  valid  [BH, S]     int8
  prot   [BH, S]     int8            protected slots always win the race
  k      [BH, S, d]  ANY/HBM         exact keys   — winners DMA'd only
  v      [BH, S, dv] ANY/HBM         exact values — winners DMA'd only
  out    [BH, G, dv] f32
  probs  [BH, S]     f32             Σ_g softmax_g(scores/√d)

Composition with the in-place decode path: this kernel is a pure READ of
the cache arrays (its fill-aware block skipping is the kernel-level
analogue of `core/cache.layer_window`'s read window), so it slots into
`decode_attention_stacked`'s read-window/storage-write split without
breaking buffer donation — the token's scatter writes
(`write_token_stacked`) land in the full-width stacked buffers after the
kernel's reads, and the cache pytree stays input-output aliased through
the decode block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
PROT_WIN = 1e30
PICKED = -1e35


def _ragged_decode_kernel(nblk_ref, q_ref, qq_ref, qs_ref, mir_ref, ms_ref,
                          ks_ref, vs_ref, valid_ref, prot_ref, k_any, v_any,
                          out_ref, probs_ref,
                          score_buf, ksel, vsel, sem,
                          *, nb, bs, s_pad, k_sel_n, scale):
    i = pl.program_id(0)
    j = pl.program_id(1)
    live_blocks = nblk_ref[i]

    @pl.when(j == 0)
    def _init():
        # dead regions keep NEG_INF: they race exactly like invalid slots
        score_buf[...] = jnp.full_like(score_buf, NEG_INF)

    # -- CAM mode: score this block iff it holds any live slot --
    @pl.when(j < live_blocks)
    def _score():
        qqf = qq_ref[0].astype(jnp.float32)                # [G, d]
        mir = mir_ref[0].astype(jnp.float32)               # [bs, d]
        raw = jax.lax.dot_general(
            qqf, mir, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [G, bs]
        ms = ms_ref[0, pl.ds(j * bs, bs)]
        raw = raw * qs_ref[0][:, None] * ms[None, :]
        validb = valid_ref[0, pl.ds(j * bs, bs)][None, :] != 0
        score_buf[:, pl.ds(j * bs, bs)] = jnp.where(validb, raw, NEG_INF)

    # -- final grid step: global CAM race + winner DMA + exact attention --
    @pl.when(j == nb - 1)
    def _select_attend():
        buf = score_buf[...]                               # [G, S_pad]
        ssel = jnp.sum(buf, axis=0, keepdims=True)         # [1, S_pad]
        ssel = jnp.where(prot_ref[0][None, :] != 0, PROT_WIN, ssel)
        iota_s = jax.lax.broadcasted_iota(jnp.int32, (1, s_pad), 1)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (k_sel_n, 1), 0)

        def _copies(slot_idx, t):
            return (pltpu.make_async_copy(k_any.at[i, pl.ds(slot_idx, 1)],
                                          ksel.at[pl.ds(t, 1)], sem.at[0]),
                    pltpu.make_async_copy(v_any.at[i, pl.ds(slot_idx, 1)],
                                          vsel.at[pl.ds(t, 1)], sem.at[1]))

        def select_one(t, carry):
            sc, onehot, prev = carry
            idx = jnp.argmax(sc).astype(jnp.int32)         # first max wins
            row = iota_s == idx
            onehot = onehot + jnp.where((iota_k == t) & row, 1.0, 0.0)
            # depth-1 DMA pipeline, as in the fused kernel

            @pl.when(t > 0)
            def _drain_prev():
                for cp in _copies(prev, t - 1):
                    cp.wait()

            for cp in _copies(idx, t):
                cp.start()
            return jnp.where(row, PICKED, sc), onehot, idx

        carry0 = (ssel, jnp.zeros((k_sel_n, s_pad), jnp.float32),
                  jnp.int32(0))
        _, onehot, last = jax.lax.fori_loop(0, k_sel_n, select_one, carry0)
        for cp in _copies(last, k_sel_n - 1):
            cp.wait()

        sel_ks = jax.lax.dot(onehot, ks_ref[0][:, None],
                             preferred_element_type=jnp.float32)
        sel_vs = jax.lax.dot(onehot, vs_ref[0][:, None],
                             preferred_element_type=jnp.float32)
        sel_valid = jax.lax.dot(
            onehot, (valid_ref[0][:, None]).astype(jnp.float32),
            preferred_element_type=jnp.float32)            # [k, 1]

        k_rows = ksel[...].astype(jnp.float32) * sel_ks    # [k, d]
        v_rows = vsel[...].astype(jnp.float32) * sel_vs    # [k, dv]
        qf = q_ref[0].astype(jnp.float32)                  # [G, d]
        logits = jax.lax.dot_general(
            qf, k_rows, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [G, k]
        logits = jnp.where(sel_valid[:, 0][None, :] > 0.5, logits, NEG_INF)
        m = jnp.max(logits, axis=-1, keepdims=True)
        e = jnp.exp(logits - m) * (logits > NEG_INF / 2)
        z = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
        out_ref[0] = jax.lax.dot(e / z, v_rows,
                                 preferred_element_type=jnp.float32)

        # -- charge-domain mode: per-slot approximate probabilities --
        lg = buf * scale
        mg = jnp.max(lg, axis=-1, keepdims=True)
        eg = jnp.exp(lg - mg) * (buf > NEG_INF / 2)
        zg = jnp.sum(eg, axis=-1, keepdims=True)
        probs_ref[0] = jnp.sum(eg / jnp.maximum(zg, 1e-30), axis=0)


def _pad_tail(x, s_pad, value=0):
    pad = s_pad - x.shape[1]
    if pad == 0:
        return x
    widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit,
                   static_argnames=("select_k", "block_s", "interpret",
                                    "block_align"))
def ragged_decode(fills: jax.Array, q: jax.Array, qq: jax.Array,
                  qscale: jax.Array, mirror: jax.Array, mscale: jax.Array,
                  kscale: jax.Array, vscale: jax.Array, valid: jax.Array,
                  prot: jax.Array, k: jax.Array, v: jax.Array, *,
                  select_k: int, block_s: int = 512,
                  interpret: bool = False, block_align: int = 0):
    """Fill-aware fused decode. Returns (out [BH,G,dv], probs [BH,S]).

    Global (num_blocks == 1) selection semantics — bitwise-compatible
    with ``ref.fused_decode_ref(..., num_blocks=1)`` whenever slots at
    and beyond ``fills[i]`` are invalid (the cache write discipline).
    Trailing padding to a block multiple is appended as invalid slots;
    block_align=0 picks the backend default (none in interpret mode,
    128 lanes on TPU)."""
    bh, g, d = q.shape
    s = mirror.shape[1]
    dv = v.shape[-1]
    assert select_k <= s, (select_k, s)
    align = block_align or (1 if interpret else 128)
    bs = -(-min(block_s, s) // align) * align
    s_pad = -(-s // bs) * bs
    nb = s_pad // bs
    mirror, k, v = (_pad_tail(x, s_pad) for x in (mirror, k, v))
    mscale, kscale, vscale, valid, prot = (
        _pad_tail(x, s_pad) for x in (mscale, kscale, vscale, valid, prot))
    nblk = jnp.clip(-(-jnp.minimum(fills.astype(jnp.int32), s) // bs),
                    0, nb)

    def blk(j, cnt):
        # dead blocks re-fetch the last live block: the pipeline sees an
        # unchanged block index and elides the mirror copy entirely
        return jnp.maximum(jnp.minimum(j, cnt - 1), 0)

    kernel = functools.partial(_ragged_decode_kernel, nb=nb, bs=bs,
                               s_pad=s_pad, k_sel_n=select_k,
                               scale=1.0 / (d ** 0.5))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, j, c: (i, 0, 0)),   # q
            pl.BlockSpec((1, g, d), lambda i, j, c: (i, 0, 0)),   # qq
            pl.BlockSpec((1, g), lambda i, j, c: (i, 0)),         # qscale
            pl.BlockSpec((1, bs, d),
                         lambda i, j, c: (i, blk(j, c[i]), 0)),   # mirror
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),     # mscale
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),     # kscale
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),     # vscale
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),     # valid
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),     # prot
            pl.BlockSpec(memory_space=pltpu.ANY),                 # k (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),                 # v (HBM)
        ],
        out_specs=[
            pl.BlockSpec((1, g, dv), lambda i, j, c: (i, 0, 0)),
            pl.BlockSpec((1, s_pad), lambda i, j, c: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, s_pad), jnp.float32),     # score buffer
            pltpu.VMEM((select_k, d), k.dtype),      # gathered K winners
            pltpu.VMEM((select_k, dv), v.dtype),     # gathered V winners
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out, probs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_pad), jnp.float32),
        ],
        interpret=interpret,
    )(nblk, q, qq, qscale.astype(jnp.float32), mirror,
      mscale.astype(jnp.float32), kscale.astype(jnp.float32),
      vscale.astype(jnp.float32), valid.astype(jnp.int8),
      prot.astype(jnp.int8), k, v)
    return out, probs[:, :s]
