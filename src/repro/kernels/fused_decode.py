"""Pallas TPU kernel — the fused single-pass pruned-decode engine.

One kernel per (batch·kv-head, slot-block) grid cell runs the whole UniCAIM
decode pipeline that `core/attention.py` otherwise composes from three
passes (approx_score → top-k → gather_attention):

  1. CAM mode     — int8 scores for the block's slots from the quantized
                    key mirror (the mirror's ONLY HBM read),
  2. CAM race     — block-local top-k selection entirely in VMEM
                    (iterative argmax; protected slots always win),
  3. current mode — per-winner DMA of the K/V rows from HBM and the exact
                    online-softmax attention contraction across blocks,
  4. charge mode  — per-slot approximate probabilities (the accumulated-
                    score update) emitted from the score scratch at the
                    last block.

Nothing round-trips HBM between the stages: the [B,Hq,S] score tensor and
[B,Hk,nb,k] index tensor of the composed path never materialize, and the
unselected bf16/int8 K/V rows are never touched — K and V live in ANY
(HBM) memory space and only the k_loc winners per block are DMA'd.

Selection semantics match the composed path: with num_blocks == 1 this is
the global `exact_topk`; with num_blocks == nb it is the hierarchical
per-block race of `select_blocks = nb` (`_gathered_attend_blocked`).

  q      [BH, G, d]   storage dtype   exact queries (GQA group per kv head)
  qq     [BH, G, d]   int8            quantized queries (CAM drive lines)
  qscale [BH, G]      f32
  mirror [BH, S, d]   int8            key mirror (int8-KV mode: K itself)
  mscale [BH, S]      f32             mirror dequant scale
  kscale [BH, S]      f32             K-row dequant scale (ones for bf16)
  vscale [BH, S]      f32             V-row dequant scale (ones for bf16)
  valid  [BH, S]      int8
  prot   [BH, S]      int8            protected (sinks + recent): race bias
  k      [BH, S, d]   ANY/HBM        exact keys   — winners DMA'd only
  v      [BH, S, dv]  ANY/HBM        exact values — winners DMA'd only
  out    [BH, G, dv]  f32
  probs  [BH, S]      f32            Σ_g softmax_g(scores/√d) — acc update
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# Selection-score sentinels. Invalid slots carry G·NEG_INF after the group
# sum, so the "already picked" marker must sit strictly below any of them
# for the race to pick distinct slots exactly like lax.top_k.
PROT_WIN = 1e30
PICKED = -1e35


def _fused_decode_kernel(q_ref, qq_ref, qs_ref, mir_ref, ms_ref, ks_ref,
                         vs_ref, valid_ref, prot_ref, k_any, v_any,
                         out_ref, probs_ref,
                         score_buf, m_sc, l_sc, o_sc, ksel, vsel, sem,
                         *, nb, bs, k_loc, scale):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        o_sc[...] = jnp.zeros_like(o_sc)

    # -- CAM mode: score this slot block against the whole query group --
    qqf = qq_ref[0].astype(jnp.float32)                    # [G, d]
    mir = mir_ref[0].astype(jnp.float32)                   # [bs, d]
    raw = jax.lax.dot_general(
        qqf, mir, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [G, bs]
    raw = raw * qs_ref[0][:, None] * ms_ref[0][None, :]
    validb = valid_ref[0][None, :] != 0                    # [1, bs]
    raw = jnp.where(validb, raw, NEG_INF)
    score_buf[:, pl.ds(j * bs, bs)] = raw

    # -- CAM race: block-local top-k on the group-summed biased scores --
    ssel = jnp.sum(raw, axis=0, keepdims=True)             # [1, bs]
    ssel = jnp.where(prot_ref[0][None, :] != 0, PROT_WIN, ssel)
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (k_loc, 1), 0)
    base = j * bs

    def _copies(slot_idx, t):
        """DMA descriptors for winner `slot_idx` → gather row `t`."""
        return (pltpu.make_async_copy(k_any.at[i, pl.ds(base + slot_idx, 1)],
                                      ksel.at[pl.ds(t, 1)], sem.at[0]),
                pltpu.make_async_copy(v_any.at[i, pl.ds(base + slot_idx, 1)],
                                      vsel.at[pl.ds(t, 1)], sem.at[1]))

    def select_one(t, carry):
        sc, onehot, prev = carry
        idx = jnp.argmax(sc).astype(jnp.int32)             # first max wins
        row = iota_s == idx                                # [1, bs]
        onehot = onehot + jnp.where((iota_k == t) & row, 1.0, 0.0)
        # depth-1 DMA pipeline: winner t-1's rows fly while t is argmax'd;
        # drain them before reusing the semaphore pair for winner t
        @pl.when(t > 0)
        def _drain_prev():
            for cp in _copies(prev, t - 1):
                cp.wait()

        for cp in _copies(idx, t):
            cp.start()
        return jnp.where(row, PICKED, sc), onehot, idx

    carry0 = (ssel, jnp.zeros((k_loc, bs), jnp.float32), jnp.int32(0))
    _, onehot, last = jax.lax.fori_loop(0, k_loc, select_one, carry0)
    for cp in _copies(last, k_loc - 1):                    # final winner
        cp.wait()

    # winner metadata rides the one-hot matmul (bytes ≪ the skipped rows)
    sel_ks = jax.lax.dot(onehot, ks_ref[0][:, None],
                         preferred_element_type=jnp.float32)   # [k_loc, 1]
    sel_vs = jax.lax.dot(onehot, vs_ref[0][:, None],
                         preferred_element_type=jnp.float32)
    sel_valid = jax.lax.dot(
        onehot, (validb[0][:, None]).astype(jnp.float32),
        preferred_element_type=jnp.float32)                    # [k_loc, 1]

    # -- current-domain mode: exact online-softmax attention over winners --
    k_rows = ksel[...].astype(jnp.float32) * sel_ks            # [k_loc, d]
    v_rows = vsel[...].astype(jnp.float32) * sel_vs            # [k_loc, dv]
    qf = q_ref[0].astype(jnp.float32)                          # [G, d]
    logits = jax.lax.dot_general(
        qf, k_rows, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale            # [G, k_loc]
    logits = jnp.where(sel_valid[:, 0][None, :] > 0.5, logits, NEG_INF)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new) * (logits > NEG_INF / 2)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_sc[...] = o_sc[...] * corr + jax.lax.dot(
        p, v_rows, preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    # -- charge-domain mode: per-slot approx probabilities for the
    #    accumulated-score table, once all blocks are scored --
    @pl.when(j == nb - 1)
    def _flush():
        out_ref[0] = o_sc[...] / jnp.maximum(l_sc[...], 1e-30)
        buf = score_buf[...]                                   # [G, S]
        lg = buf * scale
        mg = jnp.max(lg, axis=-1, keepdims=True)
        e = jnp.exp(lg - mg) * (buf > NEG_INF / 2)
        z = jnp.sum(e, axis=-1, keepdims=True)
        probs_ref[0] = jnp.sum(e / jnp.maximum(z, 1e-30), axis=0)


def _block_pad(x: jax.Array, nb: int, bs0: int, bs: int) -> jax.Array:
    """Pad each of the nb slot blocks from bs0 to bs rows IN PLACE.

    Interleaved (per-block) padding keeps the selection partition identical
    to the unpadded layout — block j still covers original slots
    [j·bs0, (j+1)·bs0) — unlike trailing padding, which would shift block
    boundaries and change which slots race each other."""
    bh = x.shape[0]
    tail = x.shape[2:]
    xb = x.reshape((bh, nb, bs0) + tail)
    widths = [(0, 0), (0, 0), (0, bs - bs0)] + [(0, 0)] * len(tail)
    return jnp.pad(xb, widths).reshape((bh, nb * bs) + tail)


@functools.partial(jax.jit,
                   static_argnames=("select_k", "num_blocks", "interpret",
                                    "block_align"))
def fused_decode(q: jax.Array, qq: jax.Array, qscale: jax.Array,
                 mirror: jax.Array, mscale: jax.Array, kscale: jax.Array,
                 vscale: jax.Array, valid: jax.Array, prot: jax.Array,
                 k: jax.Array, v: jax.Array, *, select_k: int,
                 num_blocks: int = 1, interpret: bool = False,
                 block_align: int = 0):
    """Single-pass pruned decode. Returns (out [BH,G,dv], probs [BH,S]).

    S must divide into num_blocks equal selection blocks (callers pad a
    ragged tail — see ops.fused_decode). block_align=0 picks the backend
    default: no alignment in interpret mode, 128-lane alignment on TPU
    (applied per block, preserving the selection partition)."""
    bh, g, d = q.shape
    _, s, _ = mirror.shape
    dv = v.shape[-1]
    nb = num_blocks
    assert s % nb == 0, (s, nb)
    assert select_k % nb == 0, (select_k, nb)
    k_loc = select_k // nb
    bs0 = s // nb
    assert k_loc <= bs0, (k_loc, bs0)
    align = block_align or (1 if interpret else 128)
    bs = -(-bs0 // align) * align
    s_pad = bs * nb
    if bs != bs0:
        mirror, k, v = (_block_pad(x, nb, bs0, bs) for x in (mirror, k, v))
        mscale, kscale, vscale, valid, prot = (
            _block_pad(x, nb, bs0, bs)
            for x in (mscale, kscale, vscale, valid, prot))
    kernel = functools.partial(_fused_decode_kernel, nb=nb, bs=bs,
                               k_loc=k_loc, scale=1.0 / (d ** 0.5))
    out, probs = pl.pallas_call(
        kernel,
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, j: (i, 0, 0)),     # q
            pl.BlockSpec((1, g, d), lambda i, j: (i, 0, 0)),     # qq
            pl.BlockSpec((1, g), lambda i, j: (i, 0)),           # qscale
            pl.BlockSpec((1, bs, d), lambda i, j: (i, j, 0)),    # mirror
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),          # mscale
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),          # kscale
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),          # vscale
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),          # valid
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),          # prot
            pl.BlockSpec(memory_space=pltpu.ANY),                # k (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),                # v (HBM)
        ],
        out_specs=[
            pl.BlockSpec((1, g, dv), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s_pad), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, s_pad), jnp.float32),     # score buffer
            pltpu.VMEM((g, 1), jnp.float32),         # running max
            pltpu.VMEM((g, 1), jnp.float32),         # running denom
            pltpu.VMEM((g, dv), jnp.float32),        # running output
            pltpu.VMEM((k_loc, d), k.dtype),         # gathered K winners
            pltpu.VMEM((k_loc, dv), v.dtype),        # gathered V winners
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(q, qq, qscale.astype(jnp.float32), mirror,
      mscale.astype(jnp.float32), kscale.astype(jnp.float32),
      vscale.astype(jnp.float32), valid.astype(jnp.int8),
      prot.astype(jnp.int8), k, v)
    if bs != bs0:   # drop the per-block alignment padding
        probs = probs.reshape(bh, nb, bs)[:, :, :bs0].reshape(bh, s)
    return out, probs
