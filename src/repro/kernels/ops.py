"""Public jit'd wrappers for the Pallas kernels.

Backend selection: Pallas-TPU when running on TPU, interpret mode (Python
execution of the kernel body) for CPU validation, and the pure-XLA reference
path for the multi-pod dry-run (the dry-run lowers SPMD HLO that the
roofline parser consumes — see launch/dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.approx_score import approx_score as _approx_pallas
from repro.kernels.flash_prefill import flash_prefill as _flash_pallas
from repro.kernels.gather_attention import gather_attention as _gather_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_slots(x, mult, axis, value=0):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), s


def approx_score(qq, qscale, kq, kscale, valid, block_s: int = 512,
                 backend: str = "auto"):
    """CAM-mode scoring. Shapes as in kernels/approx_score.py."""
    if backend == "xla" or (backend == "auto" and not _on_tpu()
                            and kq.shape[1] > 4096):
        # interpret mode is slow for very long S on CPU; use the oracle
        return ref.approx_score_ref(qq, qscale, kq, kscale, valid)
    kq_p, s = _pad_slots(kq, block_s, 1)
    ks_p, _ = _pad_slots(kscale, block_s, 1)
    va_p, _ = _pad_slots(valid.astype(jnp.int8), block_s, 1)
    out = _approx_pallas(qq, qscale, kq_p, ks_p, va_p, block_s=block_s,
                         interpret=not _on_tpu())
    return out[:, :, :s]


def gather_attention(q, k, v, valid, block_k: int = 512,
                     backend: str = "auto"):
    """Current-domain exact attention over gathered slots."""
    if backend == "xla":
        return ref.gather_attention_ref(q, k, v, valid)
    k_p, kk = _pad_slots(k, block_k, 1)
    v_p, _ = _pad_slots(v, block_k, 1)
    va_p, _ = _pad_slots(valid.astype(jnp.int8), block_k, 1)
    return _gather_pallas(q, k_p, v_p, va_p, block_k=block_k,
                          interpret=not _on_tpu())


def flash_prefill(q, k, v, group: int = 1, block_q: int = 256,
                  block_k: int = 256, backend: str = "auto"):
    """Prefill flash attention + accumulated column scores."""
    if backend == "xla":
        return ref.flash_prefill_ref(q, k, v, group)
    return _flash_pallas(q, k, v, group=group, block_q=block_q,
                         block_k=block_k, interpret=not _on_tpu())
