"""Public jit'd wrappers for the Pallas kernels.

Backend selection: Pallas-TPU when running on TPU, interpret mode (Python
execution of the kernel body) for CPU validation, and the pure-XLA reference
path for the multi-pod dry-run (the dry-run lowers SPMD HLO that the
roofline parser consumes — see launch/dryrun.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.approx_score import approx_score as _approx_pallas
from repro.kernels.flash_prefill import flash_prefill as _flash_pallas
from repro.kernels.fused_decode import fused_decode as _fused_pallas
from repro.kernels.gather_attention import gather_attention as _gather_pallas
from repro.kernels.ragged_decode import ragged_decode as _ragged_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_slots(x, mult, axis, value=0):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), s


def approx_score(qq, qscale, kq, kscale, valid, block_s: int = 512,
                 backend: str = "auto"):
    """CAM-mode scoring. Shapes as in kernels/approx_score.py."""
    if backend == "xla" or (backend == "auto" and not _on_tpu()
                            and kq.shape[1] > 4096):
        # interpret mode is slow for very long S on CPU; use the oracle
        return ref.approx_score_ref(qq, qscale, kq, kscale, valid)
    kq_p, s = _pad_slots(kq, block_s, 1)
    ks_p, _ = _pad_slots(kscale, block_s, 1)
    va_p, _ = _pad_slots(valid.astype(jnp.int8), block_s, 1)
    out = _approx_pallas(qq, qscale, kq_p, ks_p, va_p, block_s=block_s,
                         interpret=not _on_tpu())
    return out[:, :, :s]


def gather_attention(q, k, v, valid, block_k: int = 512,
                     backend: str = "auto"):
    """Current-domain exact attention over gathered slots."""
    if backend == "xla":
        return ref.gather_attention_ref(q, k, v, valid)
    k_p, kk = _pad_slots(k, block_k, 1)
    v_p, _ = _pad_slots(v, block_k, 1)
    va_p, _ = _pad_slots(valid.astype(jnp.int8), block_k, 1)
    return _gather_pallas(q, k_p, v_p, va_p, block_k=block_k,
                          interpret=not _on_tpu())


def fused_decode(q, qq, qscale, mirror, mscale, kscale, vscale, valid,
                 prot, k, v, select_k: int, num_blocks: int = 1,
                 backend: str = "auto", fills=None):
    """Fused single-pass pruned decode (score → select → gather → attend).

    Shapes as in kernels/fused_decode.py. The XLA fallback is one fused
    region whose gather touches only the selected rows; the Pallas kernel
    additionally keeps scores/indices out of HBM and DMAs winners row by
    row. Returns (out [BH, G, dv], probs [BH, S]).

    `fills` ([BH] int32, optional): per-row live slot counts. With global
    selection (num_blocks == 1) on the Pallas backend this dispatches the
    RAGGED kernel (kernels/ragged_decode.py), which scalar-prefetches the
    live-block counts and early-exits dead k-blocks — each lane pays its
    own O(fill) instead of O(S). The XLA fallback needs no fills: slots
    beyond fill are invalid and already masked, so its result is
    identical either way.
    """
    s = mirror.shape[1]
    if backend == "xla" or (backend == "auto" and not _on_tpu()):
        pass                       # the reference path masks, not skips
    elif fills is not None and num_blocks == 1:
        return _ragged_pallas(
            fills, q, qq, qscale, mirror, mscale, kscale, vscale, valid,
            prot, k, v, select_k=select_k, interpret=not _on_tpu())
    if s % num_blocks:
        # ragged tail: pad to equal selection blocks (both backends see the
        # same partition; pad slots are invalid so they never win the race)
        mirror, k, v = (_pad_slots(x, num_blocks, 1)[0]
                        for x in (mirror, k, v))
        mscale, kscale, vscale, valid, prot = (
            _pad_slots(x, num_blocks, 1)[0]
            for x in (mscale, kscale, vscale, valid, prot))
    if backend == "xla" or (backend == "auto" and not _on_tpu()):
        out, probs = ref.fused_decode_ref(
            q, qq, qscale, mirror, mscale, kscale, vscale, valid, prot,
            k, v, select_k=select_k, num_blocks=num_blocks)
    else:
        out, probs = _fused_pallas(
            q, qq, qscale, mirror, mscale, kscale, vscale, valid, prot,
            k, v, select_k=select_k, num_blocks=num_blocks,
            interpret=not _on_tpu())
    return out, probs[:, :s]


def flash_prefill(q, k, v, group: int = 1, block_q: int = 256,
                  block_k: int = 256, backend: str = "auto", lengths=None):
    """Prefill flash attention + accumulated column scores.

    `lengths` ([BH] int32, optional): true row counts when N is a shape
    bucket and the tail is right-padding."""
    if backend == "xla":
        return ref.flash_prefill_ref(q, k, v, group, lengths=lengths)
    return _flash_pallas(q, k, v, group=group, block_q=block_q,
                         block_k=block_k, interpret=not _on_tpu(),
                         lengths=lengths)
