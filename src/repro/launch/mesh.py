"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now (tests / smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_serve_mesh(n_shards: int | None = None):
    """1-D lane-parallel serving mesh: the first `n_shards` devices on a
    single ``data`` axis. `ServeLoop(mesh=...)` shards its lane batch
    over it — decode lanes are independent, so the decode block lowers
    to a collective-free per-shard program (`tests/test_sharded_serve`).
    On CPU, force devices first: XLA_FLAGS=--xla_force_host_platform_device_count=8.
    """
    devs = jax.devices()
    n = n_shards or len(devs)
    return jax.make_mesh((n,), ("data",), devices=devs[:n])


def make_elastic_mesh(n_devices: int | None = None):
    """Rebuild a (data, model) mesh for the CURRENT device count — the
    elastic-scaling entry point after a topology change."""
    devs = jax.devices()
    n = n_devices or len(devs)
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"),
                         devices=devs[:n])
