"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the
experiments/dryrun/*.json records."""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "whisper-base", "minitron-8b", "starcoder2-3b", "phi3-medium-14b",
    "granite-3-2b", "deepseek-v3-671b", "grok-1-314b", "zamba2-7b",
    "mamba2-1.3b", "llava-next-mistral-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str):
    recs = {}
    for f in glob.glob(os.path.join(dirpath, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], r.get("policy", "unicaim"))
        recs[key] = r
    return recs


def fmt_b(x):
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def fmt_t(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def dryrun_table(recs, mesh="16x16", policy="unicaim"):
    lines = [
        "| arch | shape | peak/dev | args/dev | flops/dev | HBM bytes/dev |"
        " coll bytes/dev | collective mix | compile |",
        "|---|---|---|---|---|---|---|---|---|".replace("|---|---|---|---|"
                                                         "---|---|---|---|---|",
                                                         "|---|---|---|---|---|---|---|---|"),
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, policy))
            if not r:
                continue
            mix = ",".join(f"{k[:2]}:{fmt_b(v)}"
                           for k, v in sorted(r["collectives"].items())
                           if k != "total" and v > 0)
            lines.append(
                f"| {arch} | {shape} | "
                f"{r['peak_bytes_per_dev'] / 2**30:.2f}GiB | "
                f"{r['arg_bytes_per_dev'] / 2**30:.2f}GiB | "
                f"{r['flops']:.2e} | {fmt_b(r['bytes_accessed'])} | "
                f"{fmt_b(r['collective_bytes'])} | {mix} | "
                f"{r['compile_s']:.0f}s |")
    return "\n".join(lines)


def roofline_table(recs, mesh="16x16", policy="unicaim"):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "bound/step | MODEL_FLOPS | useful ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, policy))
            if not r:
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt_t(r['compute_s'])} | "
                f"{fmt_t(r['memory_s'])} | {fmt_t(r['collective_s'])} | "
                f"**{r['dominant'].replace('_s', '')}** | "
                f"{fmt_t(r['step_time_bound_s'])} | "
                f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | "
                f"{r['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def notes_list(recs, mesh="16x16"):
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, "unicaim"))
            if r and r.get("notes"):
                out.append(f"- **{arch} × {shape}**: {r['notes']}")
    return "\n".join(sorted(set(out)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["dryrun", "roofline", "notes", "all"])
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    recs = load(args.dir)
    if args.section in ("dryrun", "all"):
        print("### single-pod (16x16)\n")
        print(dryrun_table(recs, "16x16"))
        print("\n### multi-pod (2x16x16)\n")
        print(dryrun_table(recs, "2x16x16"))
    if args.section in ("roofline", "all"):
        print("\n### roofline (single-pod)\n")
        print(roofline_table(recs, "16x16"))
    if args.section in ("notes", "all"):
        print("\n### per-cell notes\n")
        print(notes_list(recs))


if __name__ == "__main__":
    main()
