"""Training driver: loss, train_step, and the fault-tolerant loop.

Usage (end-to-end example):
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 200 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.data.pipeline import SyntheticSource
from repro.checkpoint.manager import CheckpointManager
from repro.models.transformer import Model
from repro.optim import adamw, schedule
from repro.runtime import fault


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Stable mean CE. logits [B,T,V] f32, targets [B,T] int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_cross_entropy(h: jax.Array, head: jax.Array,
                          targets: jax.Array, chunk: int = 512) -> jax.Array:
    """CE without materialising [B,T,V]: scans sequence chunks, projecting
    each [B,c,d] slice through the head inside the loop (§Perf: removes the
    dominant HBM term of the train step for large-vocab models)."""
    b, t, d = h.shape
    pad = (-t) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk
    hc = h.reshape(b, nc, chunk, d).swapaxes(0, 1)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)
    live = (jnp.arange(nc * chunk) < t).reshape(nc, chunk)
    headf = head.astype(jnp.float32)

    def body(tot, inp):
        hx, tg, lv = inp
        logits = hx.astype(jnp.float32) @ headf          # [B,c,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tg[..., None], -1)[..., 0]
        return tot + jnp.sum((lse - gold) * lv[None, :]), None

    from repro.runtime.flags import xscan
    tot, _ = xscan(body, jnp.zeros((), jnp.float32), (hc, tc, live))
    return tot / (b * t)


def make_loss_fn(model: Model, mtp_weight: float = 0.3,
                 loss_chunk: int = 0):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        if loss_chunk:
            h, aux = model.train_hidden(params, batch)
            ce = chunked_cross_entropy(h[:, :-1], model.head_matrix(params),
                                       tokens[:, 1:], loss_chunk)
            outs = {"aux": aux}
        else:
            outs = model.train_outputs(params, batch)
            ce = cross_entropy(outs["logits"][:, :-1], tokens[:, 1:])
        loss = ce + outs["aux"]
        metrics = {"loss": ce, "aux": outs["aux"]}
        if "mtp_logits" in outs:
            # mtp head predicts t+2 from (h_t, e_{t+1})
            mtp_ce = cross_entropy(outs["mtp_logits"][:, :-1],
                                   tokens[:, 2:])
            loss = loss + mtp_weight * mtp_ce
            metrics["mtp_loss"] = mtp_ce
        return loss, metrics
    return loss_fn


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    total_steps: int, peak_lr: float = 3e-4,
                    warmup: int = 100, loss_chunk: int = 0):
    loss_fn = make_loss_fn(model, loss_chunk=loss_chunk)

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        lr = schedule.warmup_cosine(state.opt.step, peak_lr, warmup,
                                    total_steps)
        params, opt = adamw.update(grads, state.opt, state.params, opt_cfg,
                                   lr)
        metrics = dict(metrics, lr=lr,
                       grad_norm=adamw.global_norm(grads))
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def init_train_state(model: Model, opt_cfg: adamw.AdamWConfig,
                     key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw.init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quantized-opt", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    prune = baselines.unicaim(heavy=min(448, args.seq), reserve=64,
                              select_k=64)
    model = Model(cfg, prune)
    opt_cfg = adamw.AdamWConfig(lr=args.lr,
                                quantized_state=args.quantized_opt)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt_cfg, args.steps,
                                      peak_lr=args.lr))
    src = SyntheticSource(cfg.vocab_size, args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    def data_iter(step):
        return {"tokens": jnp.asarray(src.batch(step, args.batch))}

    def on_metrics(step, m):
        if step % 10 == 0:
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")

    state, stats = fault.run_training(
        step_fn, state, data_iter, args.steps, ckpt,
        fault.FaultConfig(ckpt_every=args.ckpt_every),
        on_metrics=on_metrics)
    print(f"done: {stats.steps} steps, {stats.restarts} restarts, "
          f"final loss {stats.losses[-1] if stats.losses else float('nan'):.4f}")


if __name__ == "__main__":
    main()
