"""Host-side radix-trie prefix cache — KV reuse at admission.

Production long-context traffic is dominated by shared prompt prefixes
(system prompts, few-shot preambles). This module is the serving-side
cache that lets `ServeLoop` skip re-prefilling them: a compressed token
radix trie whose nodes carry host-side (numpy) snapshots of prefill
state, matched at admission and spliced into a decode lane through the
`repro.surgery` primitives.

Two snapshot kinds live in the trie:

``RowsEntry`` — the PRE-pruning chunked-prefill workspace restricted to
prompt rows ``[0, depth)``: per-layer K/V rows plus the accumulated
attention column sums (`models.transformer.PrefillChunkState` fields,
batch axis squeezed). After the chunks covering ``[0, depth)`` have run,
those rows/sums depend only on tokens ``[0, depth)`` — columns past a
chunk's causal reach carry exactly-zero probability mass — so resuming
the remaining chunks on top of them repeats the from-scratch f32
accumulation order bit-for-bit (`Model.resume_prefill_chunk_state`).
This is what makes prefix reuse exact under the paper's position-
dependent static pruning: the snapshot is taken BEFORE `prefill_fill`'s
sink/recent-anchored top-k rewrites the slot layout, and before the
int8 mirrors quantize, so it is a valid donor for any continuation and
for both bf16 and int8 caches. ``depth`` is always a multiple of the
engine's prefill chunk size (the resume grid).

``StateEntry`` — the finalized batch-1 `DecodeState` (+ last-position
logits) of a completed prefill. An exact-prompt hit splices it straight
into a free lane (zero prefill dispatches). It can additionally serve
as a *prefix* donor for a longer prompt only when the static pruning
left its slot layout prefix-aligned — nothing evicted, positions the
identity, full precision — which `core/cache.prefix_slot_aligned`
checks; `ServeLoop` then derives a `RowsEntry` from it at insert time
(`core/cache.cache_prefix_rows`). A pruned (rewritten) layout is
rejected as a donor: its rows are a position-scattered subset, not the
raw prefix.

Eviction is LRU under a byte budget: every insert/match touches its
entry; inserts evict least-recently-used entries (any kind) until the
budget holds. Entries larger than the whole budget are evicted
immediately — the trie never over-commits. Nodes left with no entries
and no children are pruned; pass-through nodes are left unmerged (they
cost two pointers, not cache bytes).

The trie is pure host-side bookkeeping — numpy only, no jax — so it
adds zero device dispatches to the admission path and its snapshots can
never alias live lane state (device splices copy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PrefixCache", "RowsEntry", "StateEntry"]


def _tree_nbytes(x: Any) -> int:
    """Total ndarray bytes in a nested tuple/list/dict/NamedTuple pytree."""
    if x is None:
        return 0
    if isinstance(x, np.ndarray):
        return int(x.nbytes)
    if isinstance(x, dict):
        return sum(_tree_nbytes(v) for v in x.values())
    if isinstance(x, (tuple, list)):
        return sum(_tree_nbytes(v) for v in x)
    return 0


@dataclasses.dataclass
class RowsEntry:
    """Pre-pruning workspace rows covering prompt tokens ``[0, depth)``.

    k/v: ``[L_attn, Hk, depth, dh]`` compute-dtype rows; acc:
    ``[L_attn, Hk, depth]`` f32 accumulated column sums — exactly the
    `PrefillChunkState` prefix a from-scratch chunked prefill holds
    after its first ``depth / C`` chunks (batch axis squeezed)."""
    depth: int
    k: np.ndarray
    v: np.ndarray
    acc: np.ndarray
    nbytes: int = 0

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = (_tree_nbytes(self.k) + _tree_nbytes(self.v)
                           + _tree_nbytes(self.acc))


@dataclasses.dataclass
class StateEntry:
    """Finalized batch-1 decode state of a completed prefill.

    `state` is the full DecodeState pytree with host-numpy leaves (every
    KVCache field, including quantized mirrors); `logits` the last-valid-
    position logits ``[V]`` that seed the first generated token."""
    length: int
    bucket: int
    logits: np.ndarray
    state: Any
    nbytes: int = 0

    def __post_init__(self):
        if not self.nbytes:
            self.nbytes = _tree_nbytes(self.logits) + _tree_nbytes(self.state)


class _Node:
    """Radix-trie node; `edge` is the compressed token run INTO the node."""
    __slots__ = ("edge", "children", "parent", "rows", "state")

    def __init__(self, edge: Tuple[int, ...] = (),
                 parent: Optional["_Node"] = None):
        self.edge = edge
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.rows: Optional[RowsEntry] = None
        self.state: Optional[StateEntry] = None


def _norm(tokens: Sequence[int]) -> Tuple[int, ...]:
    return tuple(int(t) for t in np.asarray(tokens).reshape(-1))


class PrefixCache:
    """Compressed token radix trie with LRU eviction under a byte budget.

    ``capacity_bytes <= 0`` disables insertion (every insert is refused)
    while keeping lookups well-defined — a convenient "off" state."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self.root = _Node()
        self.bytes = 0
        self.entries = 0
        self.inserts = 0
        self.evictions = 0
        # insertion-ordered dict as the LRU queue: lid -> (node, kind)
        self._lru: Dict[int, Tuple[_Node, str]] = {}
        self._lid: Dict[Tuple[int, str], int] = {}
        self._next_lid = 0

    # -- trie plumbing ------------------------------------------------------

    def _descend(self, tokens: Tuple[int, ...], create: bool
                 ) -> Optional[_Node]:
        """Node whose root-path spells `tokens` exactly, splitting edges
        on the way when `create`; None when absent and not creating."""
        node, i, n = self.root, 0, len(tokens)
        while i < n:
            child = node.children.get(tokens[i])
            if child is None:
                if not create:
                    return None
                child = _Node(tokens[i:], parent=node)
                node.children[tokens[i]] = child
                return child
            edge = child.edge
            m = 0
            while (m < len(edge) and i + m < n and edge[m] == tokens[i + m]):
                m += 1
            if m == len(edge):
                node, i = child, i + m
                continue
            if not create:
                return None
            # split `child`'s edge at m: node -> mid -> child
            mid = _Node(edge[:m], parent=node)
            node.children[edge[0]] = mid
            child.edge = edge[m:]
            child.parent = mid
            mid.children[edge[m]] = child
            node, i = mid, i + m
        return node

    def _prefix_nodes(self, tokens: Tuple[int, ...]
                      ) -> Iterator[Tuple[int, _Node]]:
        """Yield (depth, node) for every node whose root-path is a full
        prefix of `tokens`, shallowest first."""
        node, depth, n = self.root, 0, len(tokens)
        while depth < n:
            child = node.children.get(tokens[depth])
            if child is None:
                return
            edge = child.edge
            if depth + len(edge) > n:
                return
            for j, t in enumerate(edge):
                if tokens[depth + j] != t:
                    return
            depth += len(edge)
            node = child
            yield depth, node

    def _prune(self, node: _Node) -> None:
        """Drop entry-less childless nodes up the parent chain."""
        while (node.parent is not None and not node.children
               and node.rows is None and node.state is None):
            parent = node.parent
            del parent.children[node.edge[0]]
            node = parent

    # -- LRU ----------------------------------------------------------------

    def _touch(self, node: _Node, kind: str) -> None:
        lid = self._lid.get((id(node), kind))
        if lid is not None:
            self._lru[lid] = self._lru.pop(lid)          # move to MRU end
            return
        lid = self._next_lid
        self._next_lid += 1
        self._lru[lid] = (node, kind)
        self._lid[(id(node), kind)] = lid

    def _detach(self, node: _Node, kind: str, evicted: bool) -> None:
        entry = getattr(node, kind)
        if entry is None:
            return
        setattr(node, kind, None)
        self.bytes -= entry.nbytes
        self.entries -= 1
        if evicted:
            self.evictions += 1
        lid = self._lid.pop((id(node), kind), None)
        if lid is not None:
            self._lru.pop(lid, None)
        self._prune(node)

    def _evict_to_budget(self) -> None:
        while self.bytes > self.capacity and self._lru:
            lid = next(iter(self._lru))
            node, kind = self._lru[lid]
            self._detach(node, kind, evicted=True)

    # -- public API ---------------------------------------------------------

    def insert_rows(self, tokens: Sequence[int], entry: RowsEntry) -> bool:
        """Attach a workspace-rows donor at depth ``len(tokens)``. Returns
        False when the budget refuses it (capacity <= 0)."""
        tokens = _norm(tokens)
        assert entry.depth == len(tokens), (entry.depth, len(tokens))
        if self.capacity <= 0:
            return False
        node = self._descend(tokens, create=True)
        self._detach(node, "rows", evicted=False)        # replace in place
        node.rows = entry
        self.bytes += entry.nbytes
        self.entries += 1
        self.inserts += 1
        self._touch(node, "rows")
        self._evict_to_budget()
        return node.rows is entry

    def insert_state(self, tokens: Sequence[int], entry: StateEntry) -> bool:
        """Attach a finalized-state entry at the full-prompt node."""
        tokens = _norm(tokens)
        assert entry.length == len(tokens), (entry.length, len(tokens))
        if self.capacity <= 0:
            return False
        node = self._descend(tokens, create=True)
        self._detach(node, "state", evicted=False)
        node.state = entry
        self.bytes += entry.nbytes
        self.entries += 1
        self.inserts += 1
        self._touch(node, "state")
        self._evict_to_budget()
        return node.state is entry

    def match_rows(self, tokens: Sequence[int],
                   cap: int) -> Optional[RowsEntry]:
        """Deepest rows donor whose depth divides the prompt's prefix and
        is ``<= cap`` (the caller's resume-grid ceiling)."""
        tokens = _norm(tokens)
        best: Optional[Tuple[int, _Node]] = None
        for depth, node in self._prefix_nodes(tokens):
            if depth > cap:
                break
            if node.rows is not None:
                best = (depth, node)
        if best is None:
            return None
        _, node = best
        self._touch(node, "rows")
        return node.rows

    def match_state(self, tokens: Sequence[int]) -> Optional[StateEntry]:
        """Exact full-prompt hit, or None."""
        tokens = _norm(tokens)
        node = self._descend(tokens, create=False)
        if node is None or node.state is None:
            return None
        self._touch(node, "state")
        return node.state

    def stats(self) -> Dict[str, float]:
        return {"prefix_cache_bytes": float(self.bytes),
                "prefix_cache_entries": float(self.entries),
                "prefix_inserts": float(self.inserts),
                "prefix_evictions": float(self.evictions)}
