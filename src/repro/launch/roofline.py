"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (per device, TPU v5e):
    compute    = HLO_FLOPs / 197e12           (bf16 MXU peak per chip)
    memory     = HLO_bytes / 819e9            (HBM bandwidth per chip)
    collective = collective_bytes / 50e9      (per-link ICI bandwidth)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() of the SPMD
per-device program. collective_bytes is parsed from compiled.as_text():
the result-buffer size of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (result size ≈ bytes moved per device for
ring algorithms; noted as the standard approximation).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# matches e.g.  %ar = f32[16,128]{1,0} all-reduce(...)
#          or   %ag = (bf16[4,8]{...}, bf16[4,8]{...}) all-gather(...)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\(?[^)=]*?\)?)\s*(" + "|".join(_COLL_KINDS) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-buffer bytes per collective kind (per-device program)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    seen_done = set()
    for m in _LINE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        # avoid double counting start/done pairs: the -done line repeats the
        # buffer; count only lines NOT ending in -done
        tail = hlo_text[m.start():m.start() + 200]
        if f"{kind}-done(" in tail.split("=")[1][:80]:
            continue
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes))
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": collective_bytes / ICI_BW,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction_compute"] = (
        terms["compute_s"] / bound if bound > 0 else 0.0)
    return terms


def model_flops(cfg, shape, n_layers_active: int = None) -> float:
    """Analytic MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd) per step, using
    ACTIVE params for MoE. D = tokens processed this step (global)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the cache but
    # the matmul-FLOPs term is 2·N per token
    return 2.0 * n_active * shape.global_batch


def summarize(cell: dict) -> dict:
    """cell: raw dryrun record → roofline row."""
    terms = roofline_terms(cell["flops"], cell["bytes_accessed"],
                           cell["collective_bytes"])
    n_chips = cell["n_devices"]
    mf = cell.get("model_flops", 0.0)
    hlo_total = cell["flops"] * n_chips
    row = dict(cell)
    row.update(terms)
    row["useful_flops_ratio"] = (mf / hlo_total) if hlo_total else 0.0
    row["step_time_bound_s"] = max(terms["compute_s"], terms["memory_s"],
                                   terms["collective_s"])
    row["mfu_bound"] = (mf / n_chips / PEAK_FLOPS) / row["step_time_bound_s"] \
        if row["step_time_bound_s"] > 0 else 0.0
    return row
