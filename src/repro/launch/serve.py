"""Serving driver: lane-granular continuous batching over the UniCAIM cache.

The engine keeps a fixed number of decode *lanes* (batch slots) and a
request queue. Each request carries its own prompt (arbitrary length ≤ max)
and `max_new` budget. Admission is *grouped*: every arrived request that
pads to the same bucket is prefilled in ONE batched dispatch
(`Model.prefill_group`) and spliced into the free lanes of the live
batched `DecodeState` with ONE vectorized multi-lane insert
(`transformer.lanes_insert`) — shortest-bucket-first under load so short
prompts are never starved behind a long arrival; a lone request takes the
batch-1 path (`Model.prefill_one` + `lane_insert`). Decode runs as a
single jitted multi-step `lax.scan` over the whole lane batch — one
dispatch per block of tokens — with the state donated so XLA updates it
in place.

Termination is **in-device**: an `active` lane mask rides through the
scanned block, finished lanes stop contributing state writes, and the block
returns per-step (token, emitted) pairs so the host bookkeeping is
vectorized numpy instead of a per-token/per-lane Python loop. A lane that
hits EOS or its budget is freed and refilled from the queue mid-flight —
the fixed-budget cache (the paper's point) stays busy under realistic
mixed traffic. This is the paper's target regime: memory-bound
autoregressive decoding where per-token Python dispatch otherwise
dominates the step time.

Serving knobs are **per-lane runtime state**: temperature/top-k/top-p,
the stop token, the remaining budget, and the PRNG carry are all
[lanes]-shaped arrays threaded through the scanned block
(`decode_block_lanes`), so ONE compiled program per (steps, window)
serves any mix of greedy and sampled lanes — per-request
`SamplingParams` are honoured across the whole stream, not just the
admission-seeded first token, and knob values never recompile. The
scheduler is drain-aware (predicts lane free-times from remaining
budgets + observed EOS lengths and reserves/pre-groups queued requests
so admission fires the moment lanes free) and priority-preemptive (a
higher-priority arrival may evict the lowest-priority lane via
`lane_slice` capture; the victim requeues and later resumes
token-identically).

Requests enter through the keyword-only `Request` dataclass
(`submit(Request(prompt=..., max_new=...)) -> RequestHandle`); the
positional `submit(prompt, max_new, arrival)` shim and the all-lanes
`admit()`/`step()`/`step_block()` surface survive with a
`DeprecationWarning`, routed through the same internals. With
`prefix_cache_bytes > 0` admission consults a host-side radix-trie
prefix cache (`launch/prefix_cache.py`): exact-prompt hits splice the
cached finalized state straight into a lane, and shared-prefix hits
resume the sliced prefill from cached pre-pruning workspace rows —
bit-identical to prefilling the whole prompt from scratch.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import math
import time
import warnings
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.prefix_cache import PrefixCache, RowsEntry, StateEntry
from repro.models.transformer import Model
from repro.surgery import (cache_prefix_rows, state_lane_insert,
                           state_lane_select, state_lane_slice,
                           state_lanes_insert)


# ---------------------------------------------------------------------------
# Prompt-length buckets — shape-stable prefill.
#
# `Model.prefill_one` compiles one XLA program per distinct prompt WIDTH.
# Right-padding every prompt to a small doubling bucket grid and passing the
# true length (masked all the way through attention, charge-domain
# accumulation, and the static top-k) bounds the jit cache at len(buckets)
# programs regardless of traffic — the serving-side analogue of the paper's
# statically-shaped FeFET slot array. Two prompts padded to the same bucket
# produce bit-identical logits/caches to a same-bucket full-batch prefill.
# ---------------------------------------------------------------------------

MIN_BUCKET = 16


def bucket_length(t: int, buckets: Optional[Sequence[int]] = None) -> int:
    """Smallest bucket >= t. Default grid: powers of two from MIN_BUCKET.
    With an explicit grid, lengths beyond the largest bucket fall back to
    the exact length (correct, but one extra compile per such length)."""
    if buckets is None:
        return max(MIN_BUCKET, 2 ** math.ceil(math.log2(max(t, 1))))
    for b in buckets:
        if b >= t:
            return int(b)
    return t


def pad_to_bucket(prompt: np.ndarray,
                  buckets: Optional[Sequence[int]] = None
                  ) -> Tuple[np.ndarray, int]:
    """Right-pad `prompt` to its bucket → (padded [bucket], true length)."""
    prompt = np.asarray(prompt)
    t = len(prompt)
    b = bucket_length(t, buckets)
    if b == t:
        return prompt, t
    out = np.zeros(b, prompt.dtype)
    out[:t] = prompt
    return out, t


def greedy_generate(model: Model, params, batch, steps: int,
                    temperature: float = 0.0, key=None, top_k: int = 0,
                    top_p: float = 0.0):
    """Prefill + `steps` decode steps. Returns [B, steps] generated ids.

    One Python dispatch per token — the REFERENCE loop. Production
    serving uses the scanned paths, which support the same
    temperature/top-k/top-p sampling in-device (`ServeLoop(
    temperature=..., top_k=..., top_p=...)` / `decode_block_masked`);
    this loop shares their `_next_token` rule, so both stay
    interchangeable. `key` defaults to PRNGKey(0) when sampling
    (temperature > 0).
    """
    if temperature > 0 and key is None:
        key = jax.random.PRNGKey(0)
    logits, state = _prefill_fn(_model_key(model))(params, batch)
    decode = _decode_step_fn(_model_key(model))
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(steps):
        toks.append(tok)
        logits, state = decode(params, state, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
        else:
            sub = key
        tok = _next_token(logits, sub, temperature, top_k, top_p)
    return jnp.stack(toks, axis=1), state


def decode_block(model: Model, params, state, tok, steps: int,
                 window: Optional[int] = None):
    """`steps` greedy decode steps as one lax.scan (pure, traceable).

    tok: [B] current token → (state, next_tok [B], toks [steps, B]) where
    toks[0] == tok (the scan emits, then advances — same order as the
    per-token loop). `window` (static) runs every step over the
    `[:window]` slot prefix — the caller guarantees it covers
    max(fill) + steps (see `core/cache.decode_window`).
    """
    def body(carry, _):
        state, tok = carry
        logits, state = model.decode_step(params, state, tok,
                                          window=window)
        nxt = jnp.argmax(logits, -1)
        return (state, nxt), tok

    (state, tok), toks = jax.lax.scan(body, (state, tok), None, length=steps)
    return state, tok, toks


def _next_token(logits, key, temperature: float, top_k: int,
                top_p: float = 0.0):
    """Next-token rule shared by the decode block and admission seeding:
    argmax when temperature == 0 (key unused), else categorical over
    logits/temperature, optionally truncated to the per-row top_k
    highest logits and/or the top-p (nucleus) smallest set of tokens
    whose probability mass reaches `top_p` (top-k truncation applies
    first, matching the usual sampler convention; top_p outside (0, 1)
    disables nucleus truncation). logits: [..., V] → [...] token ids."""
    if temperature <= 0:
        return jnp.argmax(logits, -1)
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if 0.0 < top_p < 1.0:
        sl = jnp.sort(logits, axis=-1)[..., ::-1]          # descending
        p = jax.nn.softmax(sl / temperature, axis=-1)
        # keep the minimal prefix whose mass reaches top_p: a token stays
        # iff the mass BEFORE it is < top_p (the first token always does)
        keep = jnp.cumsum(p, axis=-1) - p < top_p
        cut = jnp.min(jnp.where(keep, sl, jnp.inf), -1, keepdims=True)
        logits = jnp.where(logits < cut, -jnp.inf, logits)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def decode_block_masked(model: Model, params, state, tok, active, rem,
                        eos, key, steps: int, temperature: float = 0.0,
                        top_k: int = 0, top_p: float = 0.0,
                        window: Optional[int] = None):
    """`steps` decode steps with in-device per-lane termination.

    active: [B] bool lane-live mask; rem: [B] int32 remaining budget;
    eos: RUNTIME scalar int32 (a traced argument, not a compile-time
    constant — one compiled program per `steps` serves every eos id;
    token ids are >= 0, so eos = -1 simply never matches); key: PRNG key
    threaded through the scan carry (ignored when greedy). Each step
    emits the carried token for active lanes, then advances; a lane
    deactivates on EOS or on exhausting its budget, and from then on its
    state is frozen (lane_select drops its writes) while the other lanes
    keep decoding. The EOS token itself is a stop signal, NOT an output:
    it is never emitted (it would otherwise inflate token counts and
    every tokens/s metric derived from them), while budget-terminated
    lanes still emit exactly their `rem` tokens.

    `temperature`/`top_k`/`top_p` are compile-time sampling knobs:
    temperature 0 (default) keeps the bitwise-greedy argmax path with no
    RNG in the loop; temperature > 0 samples from logits/temperature,
    optionally truncated to the top_k highest-probability tokens and/or
    the top-p nucleus per lane. `window` (static) runs every decode step
    over the `[:window]` slot prefix; the caller sizes it to cover
    max(fill over active lanes) + steps, so active-lane math is
    bit-identical to full width, while inactive lanes (whose fills the
    window may NOT cover) are safe because their state writes are
    dropped by `lane_select` and their tokens are never emitted. Returns
    (state, tok, active, rem, key, toks [steps, B], emitted [steps, B]).
    """
    inplace = model.supports_inplace_decode()

    def body(carry, _):
        state, tok, active, rem, key = carry
        if inplace:
            # zero-copy path: finished lanes are frozen at the write
            # source (dropped scatters), so no full-width lane_select
            # merge — the state pytree stays input-output aliased
            logits, state = model.decode_step(params, state, tok,
                                              window=window, active=active)
        else:
            logits, new_state = model.decode_step(params, state, tok,
                                                  window=window)
            state = state_lane_select(active, new_state, state)
        live = active & (rem > 0)      # robust to active lanes w/o budget
        emit = live & (tok != eos)
        rem = rem - emit.astype(rem.dtype)
        active = emit & (rem > 0)
        if temperature > 0:
            key, sub = jax.random.split(key)
        else:
            sub = key
        nxt = _next_token(logits, sub, temperature, top_k,
                          top_p).astype(tok.dtype)
        return (state, nxt, active, rem, key), (tok, emit)

    eos = jnp.asarray(eos, jnp.int32)
    (state, tok, active, rem, key), (toks, emitted) = jax.lax.scan(
        body, (state, tok, active, rem, key), None, length=steps)
    return state, tok, active, rem, key, toks, emitted


def _next_token_lanes(logits, keys, temperature, top_k, top_p):
    """Vectorized per-lane next-token rule: every knob is a RUNTIME array.

    logits [B, V]; keys [B, 2] per-lane PRNG subkeys; temperature/top_k/
    top_p [B]-shaped traced arrays — one compiled program serves any mix
    of greedy and sampled lanes, so knob values never recompile. Per-row
    semantics match `_next_token`: rows with temperature <= 0 take the
    bitwise argmax of the RAW logits (key unused); sampled rows truncate
    to the top_k highest logits first (top_k <= 0 disables — the kth
    threshold comes from one descending sort instead of `lax.top_k`,
    whose k must be static), then to the minimal top-p nucleus (top_p
    outside (0, 1) disables), then draw categorical(logits/temperature)
    with the row's own key.
    """
    v = logits.shape[-1]
    greedy = temperature <= 0.0
    t = jnp.where(greedy, 1.0, temperature)[:, None]       # no div-by-0
    sl = jnp.sort(logits, axis=-1)[..., ::-1]              # descending
    kth = jnp.take_along_axis(sl, (jnp.clip(top_k, 1, v) - 1)[:, None],
                              axis=-1)                     # [B, 1]
    use_k = (top_k > 0)[:, None]
    lg = jnp.where(use_k & (logits < kth), -jnp.inf, logits)
    # masking the tail of an already-sorted row keeps it sorted, so the
    # nucleus scan runs over the top-k-truncated distribution directly
    sl = jnp.where(use_k & (sl < kth), -jnp.inf, sl)
    p = jax.nn.softmax(sl / t, axis=-1)
    keep = jnp.cumsum(p, axis=-1) - p < top_p[:, None]
    cut = jnp.min(jnp.where(keep, sl, jnp.inf), -1, keepdims=True)
    use_p = ((top_p > 0.0) & (top_p < 1.0))[:, None]
    lg = jnp.where(use_p & (lg < cut), -jnp.inf, lg)
    sampled = jax.vmap(jax.random.categorical)(keys, lg / t)
    return jnp.where(greedy, jnp.argmax(logits, -1), sampled)


def decode_block_lanes(model: Model, params, state, tok, active, rem,
                       eos, keys, temperature, top_k, top_p, fault=None,
                       steps: int = 1, window: Optional[int] = None):
    """`steps` decode steps with per-lane termination AND per-lane
    sampling knobs — the engine's decode block.

    Same in-device termination contract as `decode_block_masked`, but
    every serving knob is a [B]-shaped RUNTIME array: `eos` (per-lane
    stop token; ids are >= 0 so -1 never matches), `temperature`/
    `top_k`/`top_p` (per-lane sampling, `_next_token_lanes` semantics),
    and `keys` ([B, 2] uint32 per-lane PRNG carries, split once per
    scanned step). The jit cache is keyed on (steps, window) ONLY — one
    compiled program serves arbitrary knob mixes.

    Greedy guarantees: a lane with temperature <= 0 emits the bitwise
    argmax stream (identical to `decode_block_masked`'s greedy path),
    and when NO resident lane samples a `lax.cond` skips the sampler —
    an all-greedy engine carries no RNG work and leaves `keys`
    untouched. When any lane samples, every lane's key advances once
    per step via its OWN split chain, so a lane's sampled stream is a
    function of (its initial key, steps resident) alone — independent
    of its neighbours, its lane index, and any preempt/resume boundary.

    **Non-finite sentinel.** Every step checks each lane's logits for
    NaN/Inf (a numerical fault: bad weights row, flaky interconnect,
    injected chaos). A poisoned lane is deactivated IN-DEVICE before it
    can emit from the corrupt logits and flagged in the returned
    `poison` mask; the host quarantines it and retries the request
    deterministically. The all-clean path is behind a `lax.cond` on
    `any(active & ~finite)` — when nothing is poisoned the carried
    masks pass through untouched and the block stays bitwise-identical
    to the sentinel-free engine (lanes are independent: a NaN can never
    cross the batch axis, so neighbours stay exact). `fault` (optional
    [steps, B] bool, a RUNTIME array) overwrites masked lanes' logits
    with NaN before the check — the injection point used by
    `runtime/chaos.py`; an all-False mask is a bitwise no-op.

    Returns (state, tok, active, rem, keys, poison [B],
    toks [steps, B], emitted [steps, B]).
    """
    inplace = model.supports_inplace_decode()
    eos = jnp.asarray(eos, jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    sampled_any = jnp.any(temperature > 0.0)

    def body(carry, frow):
        state, tok, active, rem, keys, poison = carry
        if inplace:
            logits, state = model.decode_step(params, state, tok,
                                              window=window, active=active)
        else:
            logits, new_state = model.decode_step(params, state, tok,
                                                  window=window)
            state = state_lane_select(active, new_state, state)
        if frow is not None:
            logits = jnp.where(frow[:, None],
                               jnp.asarray(jnp.nan, logits.dtype), logits)
        finite = jnp.all(jnp.isfinite(logits), axis=-1)
        bad = active & ~finite
        # all-clean fast path: healthy blocks take the identity branch,
        # so the sentinel never perturbs a clean lane's masks or stream
        poison, active = jax.lax.cond(
            jnp.any(bad),
            lambda p, a: (p | bad, a & finite),
            lambda p, a: (p, a), poison, active)
        live = active & (rem > 0)
        emit = live & (tok != eos)
        rem = rem - emit.astype(rem.dtype)
        active = emit & (rem > 0)

        def sample(keys):
            ks = jax.vmap(jax.random.split)(keys)          # [B, 2, 2]
            nxt = _next_token_lanes(logits, ks[:, 1], temperature,
                                    top_k, top_p)
            return ks[:, 0], nxt

        def greedy(keys):
            return keys, jnp.argmax(logits, -1)

        keys, nxt = jax.lax.cond(sampled_any, sample, greedy, keys)
        return (state, nxt.astype(tok.dtype), active, rem, keys,
                poison), (tok, emit)

    poison = jnp.zeros(tok.shape, bool)
    carry = (state, tok, active, rem, keys, poison)
    if fault is None:
        step = lambda c, _: body(c, None)
        carry, (toks, emitted) = jax.lax.scan(step, carry, None,
                                              length=steps)
    else:
        fault = jnp.asarray(fault, bool)                   # [steps, B]
        carry, (toks, emitted) = jax.lax.scan(body, carry, fault)
    state, tok, active, rem, keys, poison = carry
    return state, tok, active, rem, keys, poison, toks, emitted


def decode_block_lanes_sharded(model: Model, mesh, params, state, tok,
                               active, rem, eos, keys, temperature,
                               top_k, top_p, fault=None, steps: int = 1,
                               window: Optional[int] = None):
    """`decode_block_lanes` over a lane batch sharded ``P("data")``.

    Lanes are independent — attention, sampling, and EOS/budget masking
    never read across the batch axis — so the block is a pure data-
    parallel map over shards. Wrapping the body in `shard_map` (rather
    than relying on SPMD propagation) pins that down: every shard runs
    the per-shard program on its own contiguous block of lanes, the
    all-greedy `lax.cond` fast path (`jnp.any(temperature > 0)`) stays
    a SHARD-LOCAL reduction instead of lowering to an all-reduce on a
    knob operand, and the compiled module carries ZERO collectives on
    cache/knob operands (asserted from the HLO in
    `tests/test_sharded_serve.py`, like the PR-7 aliasing guard).

    Per-shard per-lane math is bitwise batch-size-independent (the same
    invariant grouped admission relies on), so the sharded engine
    streams token-identically to the unsharded one.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compat import shard_map
    from repro.runtime.sharding import lane_pspecs

    state_specs = lane_pspecs(state, mesh)
    lane = P("data")
    body = functools.partial(decode_block_lanes, model, steps=steps,
                             window=window)
    in_specs = (P(), state_specs, lane, lane, lane, lane,
                P("data", None), lane, lane, lane)
    args = (params, state, tok, active, rem, eos, keys, temperature,
            top_k, top_p)
    if fault is not None:
        # the [steps, lanes] fault mask shards on its LANE axis, like
        # the per-step outputs — injection stays shard-local too
        in_specs += (P(None, "data"),)
        args += (fault,)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(state_specs, lane, lane, lane, P("data", None), lane,
                   P(None, "data"), P(None, "data")),
        check_vma=False)
    return fn(*args)


def donation_mode() -> str:
    """Whether jit buffer donation is honoured on this backend: ``"on"``,
    or ``"cpu-noop"`` where `_donate_argnums` silently disables it (the
    CPU runtime ignores donation). Recorded in `ServeLoop.counters` and
    the BENCH_* rows so CPU fill-sweep floors read as copy-bound rather
    than as regressions of the in-place decode path."""
    return "cpu-noop" if jax.default_backend() == "cpu" else "on"


def _donate_argnums(*argnums):
    # buffer donation is a no-op (and warns) on CPU; donate the decode
    # state + carries everywhere it is actually honoured
    return () if donation_mode() == "cpu-noop" else argnums


# Jitted entry points are cached on the Model's full constructor identity
# (config, prune, slots, remat knobs) — all hashable — NOT on Model
# instances: a Model-keyed cache would pin jit caches (and their
# params-sized constants) for every short-lived Model/ServeLoop ever
# created. Functionally identical Models share one compiled program.


def _model_key(model: Model):
    return (model.cfg, model.prune, model.decode_slots, model.remat,
            model.remat_policy)


def _rebuild(cfg, prune, slots, remat, remat_policy) -> Model:
    return Model(cfg, prune, remat=remat, decode_slots=slots,
                 remat_policy=remat_policy)


@functools.lru_cache(maxsize=64)
def _block_fn(key, steps: int, window: Optional[int] = None):
    model = _rebuild(*key)
    return jax.jit(functools.partial(decode_block, model, steps=steps,
                                     window=window),
                   donate_argnums=_donate_argnums(1, 2))


@functools.lru_cache(maxsize=64)
def _masked_block_fn(key, steps: int, temperature: float = 0.0,
                     top_k: int = 0, top_p: float = 0.0,
                     window: Optional[int] = None):
    # keyed on `steps` (+ the static sampling knobs + the slot window)
    # ONLY: eos and the PRNG key are runtime arguments, so one compiled
    # program serves every (steps, eos) combination instead of one per
    # pair. Windows are powers of two (core/cache.decode_window), so the
    # window axis adds at most log2(slots) programs per steps value.
    model = _rebuild(*key)
    fn = functools.partial(decode_block_masked, model, steps=steps,
                           temperature=temperature, top_k=top_k,
                           top_p=top_p, window=window)
    return jax.jit(fn, donate_argnums=_donate_argnums(1, 2, 3, 4, 6))


@functools.lru_cache(maxsize=64)
def _lanes_block_fn(key, steps: int, window: Optional[int] = None,
                    mesh=None):
    # the engine's decode block — keyed on (steps, window[, mesh]) ONLY.
    # eos, the per-lane PRNG carries, and every sampling knob are runtime
    # [lanes]-shaped arguments, so one compiled program serves arbitrary
    # per-lane knob mixes (the windows axis still adds at most
    # log2(slots) programs per steps value). The scan carries (state,
    # tok, active, rem, keys) are donated wherever donation is honoured.
    # With a mesh the body runs under `shard_map` over the "data" axis —
    # same runtime-knob contract, one collective-free program per shard
    # (jax.sharding.Mesh is hashable, so it keys the same lru cache).
    model = _rebuild(*key)
    if mesh is None:
        fn = functools.partial(decode_block_lanes, model, steps=steps,
                               window=window)
    else:
        fn = functools.partial(decode_block_lanes_sharded, model, mesh,
                               steps=steps, window=window)
    return jax.jit(fn, donate_argnums=_donate_argnums(1, 2, 3, 4, 6))


@functools.lru_cache(maxsize=32)
def _lane_slice_fn(key):
    # preemption capture: one batch-1 DecodeState slice per model key
    # (the lane index is traced — one program covers every lane)
    del key
    return jax.jit(state_lane_slice)


def _resume_lane_state(state, tok, lane, fresh, next_tok):
    """Preemption resume: splice the captured batch-1 state back into a
    free lane and restore its carried (not-yet-emitted) next token —
    the exact inverse of the `_lane_slice_fn` capture, so the resumed
    stream continues token-identically (state/tok donated in place)."""
    state = state_lane_insert(state, lane, fresh)
    tok = tok.at[lane].set(next_tok.astype(tok.dtype))
    return state, tok


@functools.lru_cache(maxsize=4)
def _resume_fn():
    return jax.jit(_resume_lane_state,
                   donate_argnums=_donate_argnums(0, 1))


@functools.lru_cache(maxsize=32)
def _prefill_fn(key):
    return jax.jit(_rebuild(*key).prefill)


@functools.lru_cache(maxsize=32)
def _prefill_one_fn(key):
    return jax.jit(_rebuild(*key).prefill_one)


@functools.lru_cache(maxsize=32)
def _prefill_group_fn(key):
    return jax.jit(_rebuild(*key).prefill_group)


@functools.lru_cache(maxsize=32)
def _decode_step_fn(key):
    return jax.jit(_rebuild(*key).decode_step)


@functools.lru_cache(maxsize=32)
def _prefill_chunk_fn(key):
    # the workspace is rewritten every chunk — donate it in place
    return jax.jit(_rebuild(*key).prefill_chunk,
                   donate_argnums=_donate_argnums(1))


@functools.lru_cache(maxsize=32)
def _prefill_finalize_fn(key):
    return jax.jit(_rebuild(*key).prefill_finalize,
                   donate_argnums=_donate_argnums(1))


@functools.lru_cache(maxsize=32)
def _resume_chunk_fn(key):
    # one program per (donor depth, workspace width) pair — both shape
    # axes are bounded by the bucket grid over the chunk grid
    return jax.jit(_rebuild(*key).resume_prefill_chunk_state,
                   static_argnums=(3,))


def _jit_decode_block(model: Model, steps: int):
    return _block_fn(_model_key(model), steps)


def _admit_lane_state(state, tok, lane, fresh, logits, key,
                      temperature, top_k, top_p):
    """One-dispatch admission: splice `fresh` into `lane` and seed its
    first token from the prefill logits — via the engine's vectorized
    next-token rule, so sampling covers the FIRST generated token too.
    temperature/top_k/top_p are [1]-shaped RUNTIME arrays: one compiled
    program per bucket shape serves every override value (state/tok
    donated in place; key unused when the row is greedy)."""
    state = state_lane_insert(state, lane, fresh)
    seed = _next_token_lanes(logits[None], key[None], temperature,
                             top_k, top_p)[0]
    tok = tok.at[lane].set(seed.astype(tok.dtype))
    return state, tok


@functools.lru_cache(maxsize=2)
def _admit_fn():
    return jax.jit(_admit_lane_state,
                   donate_argnums=_donate_argnums(0, 1))


def _admit_group_state(state, tok, src, fresh, logits, keys,
                       temperature, top_k, top_p):
    """One-dispatch grouped admission: splice every mapped row of the
    batch-G `fresh` state into the live state (`lanes_insert` over the
    whole pytree) and seed each spliced lane's first token from its row
    of the group-prefill logits. keys [G, 2] and the [G]-shaped sampling
    knobs are RUNTIME arrays — each row draws from its own request's
    stream, and knob values never recompile. `src` maps live lane ->
    fresh row (-1 = lane untouched); state/tok donated in place."""
    state = state_lanes_insert(state, src, fresh)
    seeded = _next_token_lanes(logits, keys, temperature, top_k,
                               top_p)                              # [G]
    picked = jnp.take(seeded.astype(tok.dtype), jnp.maximum(src, 0))
    tok = jnp.where(src >= 0, picked, tok)
    return state, tok


@functools.lru_cache(maxsize=2)
def _admit_group_fn():
    return jax.jit(_admit_group_state,
                   donate_argnums=_donate_argnums(0, 1))


def generate_scan(model: Model, params, batch, steps: int):
    """lax.scan'd decode loop (single dispatch; production serving path).

    The decode block is jitted with the (state, token) carry donated; under
    an outer jit the inner jit inlines and the whole call stays traceable.
    """
    logits, state = _prefill_fn(_model_key(model))(params, batch)
    tok0 = jnp.argmax(logits, -1)
    state, _, toks = _jit_decode_block(model, steps)(params, state, tok0)
    return toks.swapaxes(0, 1), state


# ---------------------------------------------------------------------------
# Requests + per-request serving metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling override (same knobs as the loop-level
    `temperature`/`top_k`/`top_p`, plus an optional per-request stop
    token `eos`). Honoured across the request's WHOLE stream: the
    admission-seeded first token and every scanned decode step — the
    block's knobs are [lanes]-shaped runtime arrays, so arbitrary
    overrides share one compiled program and never recompile. Requests
    carrying an override are still admitted solo (the seeding draw is
    per-request), then decode mixed with everyone else."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    eos: Optional[int] = None        # None → the loop's eos


@dataclasses.dataclass(eq=False, kw_only=True)
class Request:
    """One generation request (keyword-only; `submit()` assigns `rid`).

    `arrival` is seconds from `run()` start (0 = already waiting);
    `submit()` keeps the queue arrival-ordered. `sampling` overrides the
    loop's sampling knobs for this request's whole stream (seeded first
    token + every scanned step); `sample_seed` pins its PRNG stream
    (both force solo admission — the seeding draw is per-request — but
    decode runs mixed). `priority` (higher = more urgent, default 0)
    picks the scheduling class: higher classes are admitted first and
    may PREEMPT the lowest-priority active lane when no lane is free
    (the victim's state is captured and it resumes token-identically
    later). `reuse_prefix=False` opts the request out of the prefix
    cache in both directions: its admission never matches a cached
    prefix and its prefill is never inserted as a donor.
    `deadline_s` is a completion deadline in seconds from ARRIVAL: a
    request still waiting or still decoding when it expires resolves
    with outcome ``"deadline"`` (partial tokens kept; its lane frees at
    the next block boundary). `RequestHandle.cancel()` resolves the
    same way with outcome ``"cancelled"``.
    Identity-compared (eq=False): the scheduler removes grouped requests
    from the queue by identity, and field equality over an ndarray
    prompt is ill-defined anyway."""
    prompt: np.ndarray
    max_new: Optional[int] = None        # None → the loop's default
    arrival: float = 0.0
    sample_seed: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    priority: int = 0
    reuse_prefix: bool = True
    deadline_s: Optional[float] = None   # completion deadline from arrival
    # engine-assigned fields — never pass these to the constructor
    rid: int = -1
    bucket: int = 0            # memoized pad width under the loop's grid
    admitted: bool = False     # lazy-prune marker for the FIFO-order deque
    resume: Optional["_ResumeState"] = None   # set while preempted
    cancelled: bool = False    # set by RequestHandle.cancel()
    retries: int = 0           # quarantine retries consumed so far
    legacy: bool = False       # came through a deprecated surface
    # first-admission PRNG draw, memoized so a quarantine RETRY replays
    # the identical sampled stream even when the seed came from the loop
    # stream (see `_seed_keys`) — never pass to the constructor either
    seed_keys: Optional[tuple] = None


class RequestHandle:
    """Ticket returned by `ServeLoop.submit(Request(...))`: a live view
    onto one request's progress (`done`, `tokens`, `stats`) without
    holding any engine state of its own."""
    __slots__ = ("rid", "_loop")

    def __init__(self, loop: "ServeLoop", rid: int):
        self.rid = rid
        self._loop = loop

    @property
    def stats(self) -> "RequestStats":
        return self._loop.stats[self.rid]

    @property
    def done(self) -> bool:
        return self.rid in self._loop._finished

    @property
    def tokens(self) -> List[int]:
        """Generated token ids so far (complete once `done`)."""
        return list(self.stats.tokens)

    @property
    def outcome(self) -> Optional[str]:
        """Terminal outcome — ``"done" | "cancelled" | "deadline" |
        "rejected" | "failed"`` — or None while the request is live."""
        return self.stats.outcome if self.done else None

    def cancel(self) -> bool:
        """Request cancellation. Returns True if the request was still
        live (it resolves with outcome ``"cancelled"`` at the next
        scheduler round — a decoding lane frees at the next block
        boundary); False if it already reached a terminal outcome."""
        return self._loop.cancel(self.rid)

    def __repr__(self) -> str:
        return f"RequestHandle(rid={self.rid}, done={self.done})"


@dataclasses.dataclass
class RequestStats:
    rid: int
    prompt_len: int
    max_new: int
    lane: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_arrival: float = 0.0     # run-relative seconds
    t_admit: float = 0.0       # prefilled + spliced into a lane; under
    #                            chunked admission this is when the LAST
    #                            prefill slice finished, so ttft still
    #                            covers the whole (time-sliced) prefill
    t_first: float = 0.0       # first generated token on the host
    t_done: float = 0.0
    occupancy: float = 0.0     # mean cache fill fraction at completion
    bucket: int = 0            # padded prefill width (== prompt_len unbucketed)
    prefill_chunks: int = 1    # dispatches the prefill was sliced into
    admit_seq: int = -1        # admission order (0 = admitted first)
    group_size: int = 1        # requests sharing this admission dispatch
    prefix_tokens: int = 0     # prompt tokens served from the prefix cache
    prefix_exact: bool = False  # whole prompt hit (state splice, no prefill)
    priority: int = 0          # scheduling class (higher = more urgent)
    preemptions: int = 0       # times this request was evicted + requeued
    outcome: str = "done"      # terminal: done|cancelled|deadline|rejected|failed
    detail: str = ""           # human-readable reason for a non-done outcome
    retries: int = 0           # quarantine retries this request consumed
    retry_after: float = 0.0   # suggested resubmit delay (outcome "rejected")
    degraded: bool = False     # admitted with a degraded-mode budget cap

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def ttft(self) -> float:
        """Time to first token (prefill-only requests: to prefill done)."""
        return self.t_first - self.t_arrival

    @property
    def decode_tps(self) -> float:
        return len(self.tokens) / max(self.t_done - self.t_admit, 1e-9)


@dataclasses.dataclass
class _ResumeState:
    """One preempted lane, captured exact to the token: the batch-1
    DecodeState slice (`_lane_slice_fn`), the carried not-yet-emitted
    next token, the unspent budget, the lane's PRNG carry, and the
    tokens emitted so far. `_admit_resumed` splices it back with zero
    prefill work; because the block advances a lane's key once per
    resident step, the resumed stream is token-identical to an
    uninterrupted run — greedy AND seeded-sampled lanes alike."""
    state: Any                 # batch-1 DecodeState (device)
    tok: int                   # next token to emit (block carry)
    rem: int                   # unspent budget
    key: np.ndarray            # [2] uint32 per-lane PRNG carry
    outputs: List[int]         # tokens emitted before the eviction


@dataclasses.dataclass
class _ChunkedPrefill:
    """Host-side progress of one in-flight time-sliced prefill."""
    req: Request
    lane: int
    bucket: int
    padded: np.ndarray
    pstate: Any                # PrefillChunkState (device)
    n_chunks: int
    next_chunk: int = 0
    x_last: Any = None         # final-stack hidden of the latest chunk
    base: int = 0              # rows [0, base) came from a prefix-cache donor
    collect: bool = False      # snapshot chunk boundaries for the trie
    # (boundary q, host acc[:, :, :q]) — acc is only valid at its exact
    # boundary (each column keeps absorbing mass from later query rows),
    # so every boundary stores its own full-prefix copy; K/V rows are
    # write-once, so ONE workspace snapshot at finalize covers them all
    snap_acc: List[Tuple[int, np.ndarray]] = dataclasses.field(
        default_factory=list)


class ServeLoop:
    """Lane-granular continuous batching: fixed decode lanes + request queue.

    New-style use::

        loop = ServeLoop(model, params, lanes=4, eos=2, block=8)
        h_a = loop.submit(Request(prompt=prompt_a, max_new=64))
        h_b = loop.submit(Request(prompt=prompt_b, max_new=16,
                                  sampling=SamplingParams(temperature=0.7),
                                  sample_seed=7))
        stats = loop.run()                    # List[RequestStats]
        h_a.done, h_a.tokens                  # per-request progress view

    Lanes are freed on EOS/budget **in-device** and refilled from the
    queue mid-flight. The positional `submit(prompt, max_new, arrival)`
    shim and the legacy all-lanes API (`admit(prompts)` +
    `step()`/`step_block()`) survive with a `DeprecationWarning` and
    drive the same engine (the legacy admit does a single full-batch
    prefill).

    **Prefix caching** (`prefix_cache_bytes > 0`). Admission consults a
    host-side radix-trie prefix cache (`launch/prefix_cache.py`) before
    touching the device. An exact-prompt hit splices the cached
    finalized DecodeState straight into the free lane — zero prefill
    dispatches, any policy/dtype. A shared-prefix hit (chunked-prefill
    path only) copies cached PRE-pruning workspace rows into a fresh
    chunk workspace (`Model.resume_prefill_chunk_state`) and dispatches
    only the suffix slices; because those rows/column-sums depend only
    on the shared tokens, the result is BIT-IDENTICAL to prefilling the
    whole prompt from scratch — for bf16 and int8 caches alike (the
    snapshot predates quantization and the slot rewrite). Completed
    prefills are inserted back: the finalized state always, plus
    per-chunk-boundary rows donors along the sliced path, and a rows
    donor derived from a finalized state when the static pruning left it
    slot-aligned (`surgery.cache_prefix_rows`) — a pruned layout is
    refused (its rows are a position-scattered subset, not the raw
    prefix). Eviction is LRU under the byte budget. Per-request opt-out:
    `Request(reuse_prefix=False)`. The `counters` dict tracks
    lookups/hits/copies/tokens-reused; `aggregate()` adds
    `prefix_hit_rate` and `prefix_dedup_ratio`.

    **Grouped admission (default).** At each admission point the
    scheduler collects every already-arrived queue request that pads to
    the SAME bucket (up to the number of free lanes) and admits the whole
    group with ONE batched prefill dispatch (`Model.prefill_group`) plus
    ONE vectorized multi-lane splice (`transformer.lanes_insert` over the
    whole DecodeState pytree) — replacing G (prefill_one + lane_insert)
    dispatch pairs. Under load (more arrived requests than free lanes)
    the group is chosen **shortest-bucket-first**, so a burst of short
    prompts is never starved behind one long arrival — bounded by aging
    (`max_head_skips`: after the FIFO head is passed over that many
    rounds in a row its bucket is forced, so long prompts can't starve
    indefinitely either); off load the FIFO head always leads the
    admission, with same-bucket followers riding along in its group (a
    later same-bucket arrival can therefore be admitted ahead of an
    earlier different-bucket one — order is FIFO per bucket, not
    globally). The group
    prefill is padded up to the next power-of-two row count (duplicating
    a real row; surplus rows are dropped by the splice's source map), so
    the jit cache holds at most log2(lanes)+1 group programs per bucket
    while a small group never pays a full lanes-row prefill. A grouped
    admission is bit-identical to admitting the same requests
    sequentially — it is purely a dispatch-count optimization
    (`group_admit=False` restores the sequential path; the `counters`
    dict tracks prefill/admit/decode dispatches either way).

    `block` sets how many tokens each dispatch decodes: the scanned block
    amortizes launch overhead across `block` tokens, at the cost of up to
    `block - 1` speculative steps after a lane hits EOS/budget (their
    outputs are masked out in-device).

    **Bucketed prefill (default).** Prompts are right-padded to a small
    doubling bucket grid and prefilled with a true-length mask, so the
    prefill jit cache holds at most len(buckets) programs no matter how
    many distinct lengths the traffic carries — mixed traffic no longer
    stalls on per-length recompiles. A bucketed prefill is bit-identical
    to a same-bucket full-batch prefill and matches an exact-length
    prefill to float-association noise (~1e-7; see `Model.prefill`).
    `buckets="auto"` uses powers of two from MIN_BUCKET; pass an explicit
    sorted tuple to pin the grid, or `buckets=None` for legacy
    exact-length prefills (one compile per distinct length).

    **Windowed decode (default).** Before each decode block the engine
    reads the active lanes' cache fills (a [L, lanes] int32 — a few
    hundred bytes of host traffic it pays anyway when it consumes the
    block's tokens) and dispatches the block over the smallest
    power-of-two slot window covering `max(fill) + block` (
    `core/cache.decode_window`). Live slots always occupy the fill
    prefix, so the windowed block is bit-identical to full width while
    every stage — CAM scoring over the mirror, the top-k race, the
    winner gather, exact attention, and the charge-domain accumulation —
    touches O(window) instead of O(slots) bytes: decode cost tracks the
    LIVE context, which is the paper's premise. The window only grows
    back to full width when a lane actually approaches the slot budget
    (where eviction/ring-wrap engages), and the pow2 grid bounds the jit
    cache at log2(slots) extra programs (`counters["decode_windows"]`
    counts the distinct windows this loop compiled). `window=None`
    disables it (always full width).

    **Sampling** (`temperature`, `top_k`, `top_p`): temperature > 0
    switches the engine from argmax to categorical sampling over
    logits/temperature (optionally truncated to the top_k most likely
    tokens and/or the minimal top-p nucleus per lane, top-k first) —
    covering the admission-seeded FIRST token as well as the scanned
    decode steps. The loop scalars are just per-lane DEFAULTS: every
    knob (plus the stop token and the PRNG carry) lives in a
    [lanes]-shaped runtime array fed to `decode_block_lanes`, and a
    request's `SamplingParams` override rides its lane for the whole
    stream. Each lane carries its OWN PRNG key (seeded from
    `sample_seed` pins via `jax.random.PRNGKey(seed)`, otherwise drawn
    from the loop stream at admission) and the block splits it once per
    scanned step — so a seeded request's sampled stream depends only on
    (seed, tokens generated): identical whether it runs solo, grouped,
    on any lane, or across a preempt/resume boundary. Greedy
    (temperature=0, the default) stays bitwise-unchanged and carries no
    RNG; knob values never recompile the block.

    **Drain-aware reservation + priority preemption.** See
    `predicted_free_blocks`, `_reserve`, and `_try_preempt`:
    with every lane busy, the scheduler predicts which lanes free
    within `reserve_blocks` decode blocks (remaining budgets bounded by
    the observed mean EOS-termination length) and pops that many queued
    requests ahead of time, so the grouped prefill fires the moment the
    lanes actually free; and a waiting request whose `priority` strictly
    outranks the lowest-priority active lane evicts that lane
    (`lane_slice` capture → requeue → token-identical resume). The
    `preemptions`/`reservations`/`reserved_admits` counters track both.

    **Scheduler cost.** The queue is per-bucket FIFO deques plus an
    arrival spill list: each `schedule()` round drains newly-arrived
    requests into their bucket deque (O(1) each, amortized), then picks
    the target bucket by scanning the O(len(buckets)) non-empty deque
    heads — NOT the O(arrived-requests) queue — so admission stays flat
    under a million-deep backlog. FIFO order within a bucket is the
    deque order; the global-FIFO head used by the off-load path and the
    aging bound is tracked with a lazily-pruned arrival-order deque.

    **Fault tolerance & graceful degradation.** `Request(deadline_s=…)`
    and `RequestHandle.cancel()` terminate waiting or decoding requests
    with outcomes ``"deadline"``/``"cancelled"`` (active lanes free at
    the next block boundary through the in-device active mask — no
    recompile; partial tokens kept). The decode block's non-finite
    sentinel flags lanes whose logits went NaN/Inf; the loop quarantines
    them and retries the request by full deterministic replay (memoized
    admission seed → token-identical stream, greedy AND sampled), up to
    `max_retries` before outcome ``"failed"``. `max_queue` bounds the
    waiting population: an overflowing submit is rejected — or sheds a
    strictly lower-priority waiter — with outcome ``"rejected"`` and a
    `retry_after` hint. A `degrade` ladder steps the engine down under
    sustained pressure (smaller decode block → tighter decode window,
    then budget caps for new admissions) and back up on hysteresis;
    token VALUES never change, only schedule shape. `chaos` attaches a
    deterministic `runtime.chaos.ChaosConfig` fault injector (logit
    corruption / dispatch stalls / shard blackouts) for testing every
    path above. Un-admittable submissions (empty prompt, `max_new<=0`,
    prompt exceeding a pinned bucket grid) resolve to structured
    rejections at submit, and `run()` is hang-proof: a stuck queue
    resolves to rejections instead of spinning (`_fail_stuck`).

    **Chunked-prefill admission** (`chunk_prefill=C`, Sarathi-style): a
    prompt whose bucket exceeds C is prefilled in C-token slices that
    interleave with decode blocks — one slice, one decode block, … — so a
    long arrival no longer head-of-line-blocks live decode lanes. The
    sliced prefill streams per-layer K/V + accumulated column sums into a
    fixed-size workspace and finalizes with the same one-shot static
    pruning; `t_admit`/ttft cover the whole sliced prefill. Requires
    `model.supports_chunked_prefill()` (plain attention stacks); others
    fall back to whole-bucket admission.
    """

    def __init__(self, model: Model, params, lanes: int,
                 prompt_len: Optional[int] = None, max_new: int = 64,
                 eos: int = -1, block: int = 1,
                 buckets: Union[str, Sequence[int], None] = "auto",
                 chunk_prefill: int = 0, group_admit: bool = True,
                 max_head_skips: int = 8, reserve_blocks: int = 1,
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 0.0, sample_seed: int = 0,
                 window: Union[str, None] = "auto",
                 window_grid: Union[str, int] = "pow2",
                 prefix_cache_bytes: int = 0,
                 mesh=None, max_retries: int = 2, max_queue: int = 0,
                 degrade: Union[str, Sequence[Dict[str, int]], None] = None,
                 degrade_high: int = 0, degrade_low: int = 0,
                 chaos=None):
        self.model = model
        self.params = params
        self.lanes = lanes
        # Data-sharded lane parallelism: `mesh` is a 1-D jax Mesh over a
        # "data" axis (or an int shard count — `launch.mesh.make_serve_mesh`
        # builds the mesh). The lane batch, per-lane knob arrays, and the
        # stacked DecodeState shard P("data") on the lane axis; decode
        # dispatches ONE collective-free per-shard program
        # (`decode_block_lanes_sharded`) and admission works one shard's
        # lane rows at a time so splice scatters stay shard-local.
        if isinstance(mesh, int):
            from repro.launch.mesh import make_serve_mesh
            mesh = make_serve_mesh(mesh)
        self.mesh = mesh
        self.shards = 1
        if mesh is not None:
            assert "data" in mesh.shape, f"serve mesh needs a data axis: {mesh}"
            assert mesh.size == mesh.shape["data"], (
                f"serve mesh must be 1-D over data: {mesh}")
            self.shards = int(mesh.shape["data"])
            assert lanes % self.shards == 0, (
                f"lanes={lanes} not divisible by {self.shards} shards")
        self.lanes_per_shard = lanes // self.shards
        self._shard_tokens = np.zeros(self.shards, np.int64)
        self._state_shardings = None          # built lazily with the state
        self.max_new = max_new
        self.eos = eos
        self.prompt_len = prompt_len          # legacy hint; not enforced
        self.block = max(1, block)
        self.buckets = (tuple(buckets)
                        if isinstance(buckets, (list, tuple)) else buckets)
        if self.buckets is not None and not model.supports_bucketed_prefill():
            self.buckets = None               # documented fallback
        self.chunk_prefill = max(0, chunk_prefill)
        if self.chunk_prefill and not model.supports_chunked_prefill():
            self.chunk_prefill = 0            # documented fallback
        self.group_admit = bool(group_admit)
        self.max_head_skips = max(0, max_head_skips)
        self._head_skips = 0
        # drain-aware reservation horizon, in decode blocks (0 = off)
        self.reserve_blocks = max(0, reserve_blocks)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.top_p = float(top_p)
        assert window in ("auto", None), window   # no silent full-width
        self.window = window                  # "auto" | None
        # window quantization grid: "pow2" (≤ log2(slots) programs) |
        # "chunk" (multiples of cfg.attn_chunk) | int (multiples of it) —
        # see core/cache.decode_window
        self.window_grid: Union[str, int] = (
            model.cfg.attn_chunk if window_grid == "chunk" else window_grid)
        assert (self.window_grid == "pow2"
                or int(self.window_grid) > 0), window_grid
        self._windows: set = set()            # distinct windows dispatched
        self._key = jax.random.PRNGKey(sample_seed)
        self._prefill = _prefill_fn(_model_key(model))
        self._prefill_one = _prefill_one_fn(_model_key(model))
        self._prefill_group = _prefill_group_fn(_model_key(model))
        self._chunk = _prefill_chunk_fn(_model_key(model))
        self._finalize = _prefill_finalize_fn(_model_key(model))
        self._resume = _resume_chunk_fn(_model_key(model))
        self.state = None
        self.tok = None
        self.active = np.zeros(lanes, bool)
        self.remaining = np.zeros(lanes, np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(lanes)]
        self.done: List[List[int]] = []
        # Per-lane serving knobs — RUNTIME arrays fed to the decode
        # block every dispatch (loop scalars are just the defaults a
        # request without overrides inherits). `_lane_keys` holds the
        # per-lane PRNG carries the block splits once per scanned step.
        self.lane_temp = np.full(lanes, self.temperature, np.float32)
        self.lane_topk = np.full(lanes, self.top_k, np.int32)
        self.lane_topp = np.full(lanes, self.top_p, np.float32)
        self.lane_eos = np.full(lanes, self.eos, np.int32)
        self._lane_keys = np.broadcast_to(
            np.asarray(self._key, np.uint32), (lanes, 2)).copy()
        self._lane_prio = np.zeros(lanes, np.int64)
        # Scheduler state: `_arrivals` holds not-yet-arrived requests in
        # arrival order; once arrived they move into their bucket's FIFO
        # deque (`_bucket_q`) and onto `_arrived_fifo` (arrival order,
        # admitted entries lazily pruned — Request.admitted flags them).
        self._arrivals: Deque[Request] = deque()
        # keyed by (-priority, bucket): min() picks the highest class
        # first, shortest bucket within it — all-default-priority
        # traffic reduces to plain shortest-bucket ordering
        self._bucket_q: Dict[Tuple[int, int], Deque[Request]] = {}
        self._arrived_fifo: Deque[Request] = deque()
        self._arrived_count = 0
        self._reserved: Deque[Request] = deque()   # drain-aware pre-group
        self._req_by_rid: Dict[int, Request] = {}
        self._drained_hwm = float("-inf")     # newest arrival drained
        self.stats: Dict[int, RequestStats] = {}
        self.completed: List[RequestStats] = []
        self._lane_rid: List[Optional[int]] = [None] * lanes
        self._next_rid = 0
        self._t0: Optional[float] = None
        self._pending: Optional[_ChunkedPrefill] = None
        self._prefill_shapes: set = set()     # (kind, width) seen this loop
        self._admit_seq = 0
        # drain-prediction inputs: generated lengths of EOS-terminated
        # requests vs. count of budget-exhausted ones (see
        # `predicted_free_blocks`)
        self._eos_lens: List[int] = []
        self._budget_done = 0
        self._finished: set = set()           # rids with t_done recorded
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(prefix_cache_bytes) if prefix_cache_bytes > 0
            else None)
        # suffix-resume (rows) donors ride the chunked-prefill path; the
        # resume grid must equal the donor prefill's accumulation grid
        # for the f32 column sums to match bit-for-bit, and finalized
        # states whose acc came from a whole-bucket prefill accumulate
        # on cfg.attn_chunk — so derive rows from them only when the
        # loop's chunk size IS cfg.attn_chunk
        self._rows_reuse = (self.prefix_cache is not None
                            and self.chunk_prefill > 0)
        # dispatch accounting: how many device calls each stage issued
        # (prefill_dispatches counts whole-prompt/group prefills and
        # chunked finalizes; chunk slices are tallied separately)
        # `donation` is a string-valued counter: whether the donated
        # decode-block buffers are actually reused on this backend (CPU
        # silently no-ops donation, so its fill-sweep floor is copy-bound)
        self.counters: Dict[str, Any] = {
            "prefill_dispatches": 0, "admit_dispatches": 0,
            "chunk_dispatches": 0, "decode_blocks": 0,
            "grouped_admissions": 0, "grouped_requests": 0,
            "decode_windows": 0, "decode_block_programs": 0,
            "preemptions": 0, "reservations": 0, "reserved_admits": 0,
            "donation": donation_mode(),
            "prefix_lookups": 0, "prefix_hits": 0,
            "prefix_exact_hits": 0, "prefix_copies": 0,
            "prefix_tokens_reused": 0,
            "prefix_inserts": 0, "prefix_evictions": 0,
            "preempt_cache_inserts": 0,
        }
        # per-(priority, bucket) EOS-length samples — drain prediction
        # uses a class-local mean once a class has >= 4 EOS completions,
        # so short bursty and long bulk traffic stop polluting each
        # other's free-lane forecasts (global mean is the fallback)
        self._eos_by_class: Dict[Tuple[int, int], List[int]] = {}
        # -- fault tolerance -------------------------------------------------
        # quarantine retries per request before outcome "failed"
        self.max_retries = max(0, max_retries)
        # bounded admission: > 0 caps the WAITING population; an
        # overflowing submit is rejected (or sheds a strictly
        # lower-priority waiter) with outcome "rejected" + retry_after
        self.max_queue = max(0, max_queue)
        # degradation ladder: each level maps to overrides applied under
        # queue pressure — "block" (smaller decode block → tighter decode
        # window via `decode_window(fill, steps)`, token values
        # UNCHANGED) and "max_new_cap" (budget cap for NEW admissions).
        # None disables; "auto" derives a two-level ladder from `block`.
        if degrade == "auto":
            degrade = ({"block": max(1, self.block // 2)},
                       {"block": max(1, self.block // 4),
                        "max_new_cap": 4 * self.block})
        self.degrade_ladder: Tuple[Dict[str, int], ...] = (
            tuple(degrade) if degrade else ())
        # pressure thresholds on the WAITING population (hysteresis:
        # step down at >= high with every lane busy, back up at <= low)
        self.degrade_high = degrade_high if degrade_high > 0 else 2 * lanes
        self.degrade_low = max(0, degrade_low)
        self._degrade_level = 0
        self.chaos = chaos            # Optional[runtime.chaos.ChaosConfig]
        self._rounds = 0              # scheduler rounds (run() iterations)
        self._blackout_on = False
        self._block_s_ema: Optional[float] = None  # wall secs / decode block
        self.counters.update({
            "deadline_expired": 0, "cancelled_requests": 0,
            "rejected_requests": 0, "shed_requests": 0,
            "quarantined_lanes": 0, "retried_requests": 0,
            "failed_requests": 0, "degrade_down": 0, "degrade_up": 0,
            "chaos_faults": 0, "chaos_stalls": 0, "chaos_blackouts": 0,
        })

    # -- time ----------------------------------------------------------------

    def _now(self) -> float:
        return 0.0 if self._t0 is None else time.monotonic() - self._t0

    # -- request intake ------------------------------------------------------

    def submit(self, request, max_new: Optional[int] = None,
               arrival: float = 0.0):
        """Queue one request.

        New style: ``submit(Request(prompt=..., max_new=...)) ->
        RequestHandle``. The positional form ``submit(prompt, max_new,
        arrival) -> rid`` is deprecated (it predates the Request
        dataclass being public API) and warns."""
        if isinstance(request, Request):
            if max_new is not None or arrival != 0.0:
                raise TypeError(
                    "submit(Request(...)) takes no extra arguments — set "
                    "max_new/arrival on the Request")
            return self._enqueue(request)
        warnings.warn(
            "submit(prompt, max_new, arrival) is deprecated; pass "
            "submit(Request(prompt=..., max_new=..., arrival=...)) and "
            "use the returned RequestHandle",
            DeprecationWarning, stacklevel=2)
        req = Request(prompt=np.asarray(request), max_new=max_new,
                      arrival=float(arrival), legacy=True)
        return self._enqueue(req).rid

    def _enqueue(self, req: Request) -> RequestHandle:
        if req.rid >= 0:
            raise ValueError(f"Request already submitted (rid={req.rid})")
        req.prompt = np.asarray(req.prompt)
        if req.max_new is None:
            req.max_new = self.max_new
        req.rid = self._next_rid
        self._next_rid += 1
        self._req_by_rid[req.rid] = req
        arrival = float(req.arrival)
        # un-admittable shapes resolve to a STRUCTURED rejection at
        # submit instead of wedging `run()` (outcome "rejected"). The
        # deprecated positional surface keeps its documented
        # prefill-only max_new=0 behaviour (outcome "done").
        reason = self._unadmittable(req)
        if reason is not None:
            return self._reject_new(req, reason)
        if self.max_queue and self._waiting_count() >= self.max_queue:
            victim = self._shed_candidate(req)
            if victim is None:
                return self._reject_new(req, "queue full", backpressure=True)
            self._shed(victim)
        req.bucket = self._bucket_of(req)     # memoized for the scheduler
        if arrival < self._drained_hwm:
            # backdated submit landing AMONG already-drained requests:
            # splice it into the arrived structures at its arrival rank
            # (O(arrived) — a rare replay/test path; the hot path below
            # stays O(1)/O(log)) so the global-FIFO head and the aging
            # bound keep protecting the true oldest request
            self._insert_arrived(req)
        elif self._arrivals and arrival < self._arrivals[-1].arrival:
            # keep arrival order (FIFO among ties) — the drain pops head
            idx = next(i for i, r in enumerate(self._arrivals)
                       if r.arrival > arrival)
            self._arrivals.insert(idx, req)
        else:
            self._arrivals.append(req)
        self.stats[req.rid] = RequestStats(req.rid, len(req.prompt),
                                           req.max_new, t_arrival=arrival,
                                           priority=req.priority)
        return RequestHandle(self, req.rid)

    # -- structured rejection + backpressure ---------------------------------

    def _unadmittable(self, req: Request) -> Optional[str]:
        """Reason this request can NEVER be served (reject at submit
        instead of wedging `run()` later), or None when admittable.
        Legacy-surface requests keep the documented prefill-only
        `max_new=0` behaviour and are never shape-rejected here."""
        if req.legacy:
            return None
        if len(req.prompt) == 0:
            return "empty prompt"
        if req.max_new <= 0:
            return "max_new <= 0 generates nothing (prefill-only runs " \
                   "ride the legacy surface)"
        if isinstance(self.buckets, tuple) and self.buckets \
                and len(req.prompt) > max(self.buckets):
            return (f"prompt length {len(req.prompt)} exceeds every "
                    f"bucket of the pinned grid {self.buckets}")
        return None

    def _waiting_count(self) -> int:
        """Current waiting population: arrived-but-unadmitted + future
        arrivals + drain-reserved (everything `max_queue` bounds)."""
        return (self._arrived_count + len(self._arrivals)
                + len(self._reserved))

    def _retry_after(self) -> float:
        """Suggested resubmit delay for a backpressure rejection: the
        waiting population's predicted drain time under the observed
        per-block wall clock (a coarse, monotonic-in-depth hint)."""
        blk = self._block_s_ema if self._block_s_ema is not None else 0.05
        depth = self._waiting_count() / max(self.lanes, 1)
        tokens = np.mean([r.max_new for r in self._arrived_fifo
                          if not r.admitted] or [self.max_new])
        return depth * math.ceil(float(tokens) / self.block) * blk

    def _finish_queued(self, req: Request, outcome: str,
                       detail: str = "") -> None:
        """Resolve a request that never reached (or no longer holds) a
        lane with a terminal outcome — the queued-side twin of
        `_finish_lane`."""
        st = self.stats[req.rid]
        now = self._now()
        if req.resume is not None:             # preempted mid-stream:
            st.tokens = list(req.resume.outputs)   # keep partial tokens
            req.resume = None
        st.outcome = outcome
        st.detail = detail
        st.t_done = max(now, st.t_arrival)
        if st.t_first < st.t_admit:
            st.t_first = st.t_done
        req.admitted = True                    # lazy-prune marker
        self.completed.append(st)
        self.done.append(st.tokens)
        self._finished.add(req.rid)
        self._req_by_rid.pop(req.rid, None)

    def _reject_new(self, req: Request, reason: str,
                    backpressure: bool = False) -> RequestHandle:
        """Resolve a just-submitted request as "rejected" without ever
        queueing it (structured refusal: the handle is immediately done,
        `stats.retry_after` hints when to resubmit under backpressure)."""
        self.stats[req.rid] = RequestStats(
            req.rid, len(req.prompt), max(req.max_new, 0),
            t_arrival=float(req.arrival), priority=req.priority)
        self.counters["rejected_requests"] += 1
        self._finish_queued(req, "rejected", reason)
        if backpressure:
            self.stats[req.rid].retry_after = self._retry_after()
        return RequestHandle(self, req.rid)

    def _shed_candidate(self, new: Request) -> Optional[Request]:
        """Lowest-priority waiter strictly below `new`'s class — the
        latest arrival in the worst waiting class (least invested) —
        or None when nothing outranks: then `new` itself is rejected.
        O(len(buckets) + future arrivals), not O(backlog)."""
        worst: Optional[Request] = None
        if self._bucket_q:
            key = max(self._bucket_q)          # (-prio, bucket): max = worst
            worst = self._bucket_q[key][-1]
        for r in self._arrivals:               # future arrivals spill list
            if worst is None or r.priority < worst.priority or (
                    r.priority == worst.priority
                    and r.arrival >= worst.arrival):
                worst = r
        if worst is None or worst.priority >= new.priority:
            return None
        return worst

    def _shed(self, victim: Request) -> None:
        """Drop one waiting request to make room (outcome "rejected",
        counted as shed; its handle stays valid)."""
        try:
            self._arrivals.remove(victim)
        except ValueError:
            dq = self._bucket_q.get(self._qkey(victim))
            dq.remove(victim)
            if not dq:
                del self._bucket_q[self._qkey(victim)]
            self._arrived_count -= 1
        self.counters["shed_requests"] += 1
        self.counters["rejected_requests"] += 1
        self._finish_queued(victim, "rejected", "shed under backpressure")
        self.stats[victim.rid].retry_after = self._retry_after()

    # -- cancellation + deadlines --------------------------------------------

    def cancel(self, rid: int) -> bool:
        """Flag one request for cancellation (see RequestHandle.cancel).
        Resolution happens at the next scheduler round: a waiting
        request resolves when popped (or swept), an active lane frees at
        the next block boundary through the in-device active mask."""
        if rid in self._finished:
            return False
        req = self._req_by_rid.get(rid)
        if req is None:
            return False
        req.cancelled = True
        return True

    def _deadline_over(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None
                and now >= self.stats[req.rid].t_arrival + req.deadline_s)

    def _resolve_dead(self, req: Request, now: Optional[float] = None
                      ) -> bool:
        """Resolve a WAITING request that was cancelled or whose
        deadline expired (True = it is gone; don't admit it). Called at
        every pop point so the scheduler's O(buckets) round never scans
        the backlog for corpses."""
        now = self._now() if now is None else now
        if req.cancelled:
            self.counters["cancelled_requests"] += 1
            self._finish_queued(req, "cancelled")
            return True
        if self._deadline_over(req, now):
            self.counters["deadline_expired"] += 1
            self._finish_queued(req, "deadline",
                                f"deadline_s={req.deadline_s} expired "
                                "before admission")
            return True
        return False

    def _sweep_lanes(self, now: float) -> None:
        """Terminate ACTIVE lanes whose request was cancelled or hit its
        deadline: clear the host active mask (the next dispatch's
        in-device mask drops their writes — no recompile) and finish the
        lane with partial tokens. Runs every scheduler round, so an
        expired lane frees within one decode block."""
        for lane in np.flatnonzero(self.active):
            lane = int(lane)
            rid = self._lane_rid[lane]
            req = self._req_by_rid.get(rid) if rid is not None else None
            if req is None:                    # legacy admit() batch
                continue
            if req.cancelled:
                self.counters["cancelled_requests"] += 1
                outcome, detail = "cancelled", ""
            elif self._deadline_over(req, now):
                self.counters["deadline_expired"] += 1
                outcome = "deadline"
                detail = f"deadline_s={req.deadline_s} expired mid-decode"
            else:
                continue
            self.active[lane] = False
            self.remaining[lane] = 0
            self._finish_lane(lane, now, outcome=outcome, detail=detail)

    def _qkey(self, req: Request) -> Tuple[int, int]:
        """Scheduling-class deque key: sorts as (-priority, bucket)."""
        return (-req.priority, req.bucket)

    def _insert_arrived(self, req: Request) -> None:
        """Insert at arrival rank (after ties) into the arrived deques."""
        def rank(dq):
            for i, r in enumerate(dq):
                if r.arrival > req.arrival:
                    return i
            return len(dq)
        self._arrived_fifo.insert(rank(self._arrived_fifo), req)
        dq = self._bucket_q.setdefault(self._qkey(req), deque())
        dq.insert(rank(dq), req)
        self._arrived_count += 1

    @property
    def queue(self) -> List[Request]:
        """Waiting (un-admitted) requests in arrival order — arrived
        first, then future arrivals. A snapshot view over the scheduler's
        per-bucket deques + arrival spill list (read-only)."""
        waiting = [r for r in self._arrived_fifo if not r.admitted]
        return waiting + list(self._arrivals)

    def _drain_arrivals(self, now: float) -> None:
        """Move every request whose arrival time has passed into its
        bucket's FIFO deque. O(newly arrived) — each request is moved
        exactly once over the loop's lifetime."""
        while self._arrivals and self._arrivals[0].arrival <= now:
            req = self._arrivals.popleft()
            self._bucket_q.setdefault(self._qkey(req), deque()).append(req)
            self._arrived_fifo.append(req)
            self._arrived_count += 1
            self._drained_hwm = max(self._drained_hwm, req.arrival)

    def _fifo_head(self) -> Optional[Request]:
        """Oldest arrived, un-admitted request (lazy-pruned deque head)."""
        fifo = self._arrived_fifo
        while fifo and fifo[0].admitted:
            fifo.popleft()
        return fifo[0] if fifo else None

    @staticmethod
    def _needs_solo(req: Request) -> bool:
        """Per-request sampling/seed overrides draw their seed at the
        admission-seeding dispatch, which is per-request — so such a
        request never shares a grouped admission (it still decodes mixed
        with everyone else). A preempted request resuming splices its
        captured state instead of prefilling, so it is always solo."""
        return (req.sampling is not None or req.sample_seed is not None
                or req.resume is not None)

    def _take_bucket(self, key: Tuple[int, int], n: int) -> List[Request]:
        """Pop up to `n` FIFO requests from one class deque; a request
        needing a solo admission (sampling overrides / a resume splice)
        terminates (or solely forms) the group."""
        dq = self._bucket_q.get(key)
        group: List[Request] = []
        while dq and len(group) < n:
            if group and self._needs_solo(dq[0]):
                break
            req = dq.popleft()
            req.admitted = True
            group.append(req)
            if self._needs_solo(req):
                break
        if dq is not None and not dq:
            del self._bucket_q[key]
        self._arrived_count -= len(group)
        return group

    def _take_reserved(self, n: int) -> List[Request]:
        """Pop a same-bucket prefix of the reservation queue (≤ n), with
        the same solo boundaries as `_take_bucket`."""
        rq = self._reserved
        group: List[Request] = []
        while rq and len(group) < n:
            if group and (self._needs_solo(rq[0])
                          or rq[0].bucket != group[0].bucket):
                break
            req = rq.popleft()
            group.append(req)
            if self._needs_solo(req):
                break
        return group

    # -- admission -----------------------------------------------------------

    def _ensure_state(self):
        if self.state is None:
            self.state = self.model.init_decode_state(self.lanes)
            self.tok = jnp.zeros((self.lanes,), jnp.int32)
            self._pin_state()

    def _lane_sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P("data"))

    def _pin_state(self) -> None:
        """Re-commit the live state to the lane-sharded layout
        (`runtime.sharding.lane_pspecs`: DecodeState P("data") on the
        lane axis, tok P("data")). Admission/resume splices run as plain
        jits whose inferred output shardings may drift; pinning before
        each decode dispatch keeps the shard_map'd block's input layout
        stable so it compiles ONCE and never reshards mid-stream. A
        no-op without a mesh, and free when the layout already matches
        (device_put to an identical sharding is the identity)."""
        if self.mesh is None or self.state is None:
            return
        from repro.runtime.sharding import lane_shardings
        if self._state_shardings is None:
            self._state_shardings = lane_shardings(self.state, self.mesh)
        self.state = jax.device_put(self.state, self._state_shardings)
        self.tok = jax.device_put(self.tok, self._lane_sharding())

    def _padded_prompt(self, req: Request) -> Tuple[np.ndarray, int]:
        """(padded prompt, bucket width) under this loop's bucket policy."""
        prompt = np.asarray(req.prompt)
        if self.buckets is None:
            return prompt, len(prompt)
        grid = None if self.buckets == "auto" else self.buckets
        padded, _ = pad_to_bucket(prompt, grid)
        return padded, len(padded)

    def _bucket_of(self, req: Request) -> int:
        """Bucket width alone (no padding allocation — scheduler hot path)."""
        if self.buckets is None:
            return len(req.prompt)
        grid = None if self.buckets == "auto" else self.buckets
        return bucket_length(len(req.prompt), grid)

    def _admit_lane(self, lane: int, req: Request):
        """Prefill one request (whole-bucket) and splice it into `lane`.
        Consults the prefix cache for an exact-prompt hit first, and
        inserts the finished prefill back as a donor."""
        self._ensure_state()
        hit, _ = self._cache_match(req, rows_cap=None)
        if hit is not None:
            self._splice_cached(lane, req, hit)
            return
        padded, bucket = self._padded_prompt(req)
        if bucket == len(req.prompt) and self.buckets is None:
            self._prefill_shapes.add(("exact", bucket))
            logits, fresh = self._prefill_one(self.params, jnp.asarray(padded))
        else:
            self._prefill_shapes.add(("bucket", bucket))
            logits, fresh = self._prefill_one(
                self.params, jnp.asarray(padded),
                jnp.asarray(len(req.prompt), jnp.int32))
        self.counters["prefill_dispatches"] += 1
        self._splice(lane, req, logits, fresh, bucket=bucket)
        self._cache_insert_finalized(req, logits, fresh, bucket)

    def _sample_key(self):
        """Fresh subkey for an admission seed when sampling; when greedy
        the key is passed through untouched (and unused in-device), so
        the greedy stream stays bitwise-identical to pre-sampling code."""
        if self.temperature <= 0:
            return self._key
        self._key, sub = jax.random.split(self._key)
        return sub

    def _req_sampling(self, req: Request) -> Tuple[float, int, float]:
        """(temperature, top_k, top_p) for this request's seeded first
        token: its SamplingParams override, else the loop knobs."""
        sp = req.sampling
        if sp is None:
            return self.temperature, self.top_k, self.top_p
        return float(sp.temperature), int(sp.top_k), float(sp.top_p)

    def _seed_keys(self, req: Request):
        """(admission draw key, lane PRNG carry) for one request. A
        pinned `sample_seed` derives both from PRNGKey(seed); otherwise
        from the loop stream — advanced only when the effective
        temperature actually samples, so greedy admissions leave the
        stream untouched (and both keys unused in-device). The lane
        carry is what the decode block splits once per scanned step:
        a seeded request's sampled stream is a function of (seed,
        tokens generated) alone — identical solo, grouped, on any lane,
        or across a preempt/resume boundary. The pair is memoized on
        the Request at first admission so a quarantine RETRY replays
        the identical stream even when the seed came from the loop
        stream (a re-draw would silently fork the tokens)."""
        if self._req_sampling(req)[0] <= 0:
            return self._key, self._key        # unused in-device
        if req.seed_keys is not None:
            return req.seed_keys
        if req.sample_seed is not None:
            base = jax.random.PRNGKey(req.sample_seed)
        else:
            self._key, base = jax.random.split(self._key)
        req.seed_keys = tuple(jax.random.split(base))
        return req.seed_keys

    def _splice(self, lane: int, req: Request, logits, fresh,
                bucket: int, prefill_chunks: int = 1,
                prefix_tokens: int = 0):
        """Insert a freshly prefilled batch-1 state into a free lane."""
        t, k, p = self._req_sampling(req)
        draw, carry = self._seed_keys(req)
        self.state, self.tok = _admit_fn()(
            self.state, self.tok, lane, fresh, logits, draw,
            jnp.asarray([t], jnp.float32), jnp.asarray([k], jnp.int32),
            jnp.asarray([p], jnp.float32))
        self.counters["admit_dispatches"] += 1
        self._register_admit(lane, req, bucket=bucket,
                             prefill_chunks=prefill_chunks,
                             prefix_tokens=prefix_tokens, lane_key=carry)

    # -- prefix cache --------------------------------------------------------

    def _cache_match(self, req: Request, rows_cap: Optional[int]
                     ) -> Tuple[Optional[StateEntry], Optional[RowsEntry]]:
        """One admission-time lookup: (exact-state hit, rows donor) —
        at most one is non-None. `rows_cap` bounds the usable donor depth
        (the deepest chunk boundary strictly inside the prompt); None
        skips the rows search (whole-bucket path)."""
        pc = self.prefix_cache
        if pc is None or not req.reuse_prefix:
            return None, None
        self.counters["prefix_lookups"] += 1
        st = pc.match_state(req.prompt)
        if st is not None:
            self.counters["prefix_hits"] += 1
            self.counters["prefix_exact_hits"] += 1
            return st, None
        if rows_cap is not None and rows_cap >= self.chunk_prefill:
            rows = pc.match_rows(req.prompt, rows_cap)
            if rows is not None:
                self.counters["prefix_hits"] += 1
                return None, rows
        return None, None

    def _splice_cached(self, lane: int, req: Request, entry: StateEntry):
        """Admit from an exact-prompt hit: splice the cached finalized
        state straight into `lane` — zero prefill dispatches. The cached
        logits seed the first token through the request's sampling rule,
        so a greedy twin of the original request reproduces its stream."""
        fresh = jax.tree.map(jnp.asarray, entry.state)
        t, k, p = self._req_sampling(req)
        draw, carry = self._seed_keys(req)
        self.state, self.tok = _admit_fn()(
            self.state, self.tok, lane, fresh, jnp.asarray(entry.logits),
            draw, jnp.asarray([t], jnp.float32),
            jnp.asarray([k], jnp.int32), jnp.asarray([p], jnp.float32))
        self.counters["admit_dispatches"] += 1
        self.counters["prefix_copies"] += 1
        self.counters["prefix_tokens_reused"] += entry.length
        self._register_admit(lane, req, bucket=entry.bucket,
                             prefill_chunks=0, prefix_tokens=entry.length,
                             prefix_exact=True, lane_key=carry)

    def _sync_cache_counters(self):
        pc = self.prefix_cache
        self.counters["prefix_inserts"] = pc.inserts
        self.counters["prefix_evictions"] = pc.evictions

    def _cache_insert_finalized(self, req: Request, logits, fresh,
                                bucket: int):
        """Insert a completed whole-bucket prefill into the trie: the
        finalized state always; additionally a rows donor when the
        static pruning left the prefix slot-aligned (nothing evicted,
        identity positions, full precision), the prompt length sits on
        the resume chunk grid, and that grid equals the donor's
        accumulation grid (cfg.attn_chunk) so the f32 column sums carry
        the exact from-scratch accumulation order."""
        pc = self.prefix_cache
        if pc is None or not req.reuse_prefix:
            return
        host_state = jax.tree.map(np.asarray, fresh)
        pc.insert_state(req.prompt, StateEntry(
            length=len(req.prompt), bucket=bucket,
            logits=np.asarray(logits), state=host_state))
        c = self.chunk_prefill
        n = len(req.prompt)
        if (self._rows_reuse and n % c == 0
                and c == self.model.cfg.attn_chunk
                and getattr(host_state, "kv", None) is not None):
            rows = cache_prefix_rows(host_state.kv, n)
            if rows is not None:
                pc.insert_rows(req.prompt, RowsEntry(n, *rows))
        self._sync_cache_counters()

    def _admit_group(self, lanes: List[int], group: List[Request]):
        """Admit G same-bucket requests with ONE batched prefill dispatch
        and ONE multi-lane splice. The token batch is padded UP to the
        next power-of-two row count (duplicating row 0, a well-formed
        real prompt) so the prefill jit cache holds at most
        log2(lanes)+1 group programs per bucket while small groups on
        wide-lane engines don't pay a full lanes-row prefill; the
        splice's source map drops the surplus rows. Bit-identical to
        admitting the same requests sequentially via `_admit_lane`."""
        self._ensure_state()
        padded = [self._padded_prompt(r)[0] for r in group]
        bucket = len(padded[0])
        g = len(group)
        gp = min(1 << (g - 1).bit_length(), self.lanes)      # pow2 rows
        rows = np.stack(padded)                              # [G, W]
        lengths = np.fromiter((len(r.prompt) for r in group), np.int32, g)
        if g < gp:
            pad_rows = np.broadcast_to(rows[:1], (gp - g, bucket))
            rows = np.concatenate([rows, pad_rows], axis=0)
            lengths = np.concatenate(
                [lengths, np.full(gp - g, lengths[0], np.int32)])
        src = np.full(self.lanes, -1, np.int32)
        for i, lane in enumerate(lanes):
            src[lane] = i
        if self.buckets is None:               # exact-width group
            self._prefill_shapes.add(("group-exact", bucket, gp))
            logits, fresh = self._prefill_group(self.params,
                                                jnp.asarray(rows))
        else:
            self._prefill_shapes.add(("group", bucket, gp))
            logits, fresh = self._prefill_group(self.params,
                                                jnp.asarray(rows),
                                                jnp.asarray(lengths))
        self.counters["prefill_dispatches"] += 1
        # per-row seeding: each request draws from its OWN stream and
        # gets its own lane PRNG carry (pad rows mirror row 0 — their
        # draws are dropped by the splice's source map anyway)
        t_arr = np.empty(gp, np.float32)
        k_arr = np.empty(gp, np.int32)
        p_arr = np.empty(gp, np.float32)
        draws = np.empty((gp, 2), np.uint32)
        carries: List[np.ndarray] = []
        for i, r in enumerate(group):
            t_arr[i], k_arr[i], p_arr[i] = self._req_sampling(r)
            draw, carry = self._seed_keys(r)
            draws[i] = np.asarray(draw, np.uint32)
            carries.append(np.asarray(carry, np.uint32))
        t_arr[g:], k_arr[g:], p_arr[g:] = t_arr[0], k_arr[0], p_arr[0]
        draws[g:] = draws[0]
        self.state, self.tok = _admit_group_fn()(
            self.state, self.tok, jnp.asarray(src), fresh, logits,
            jnp.asarray(draws), jnp.asarray(t_arr), jnp.asarray(k_arr),
            jnp.asarray(p_arr))
        self.counters["admit_dispatches"] += 1
        self.counters["grouped_admissions"] += 1
        self.counters["grouped_requests"] += g
        for lane, req, carry in zip(lanes, group, carries):
            self._register_admit(lane, req, bucket=bucket, group_size=g,
                                 lane_key=carry)

    def _set_lane_knobs(self, lane: int, req: Request) -> None:
        """Load one lane's runtime knob slots from the request (its
        SamplingParams override, else the loop defaults)."""
        t, k, p = self._req_sampling(req)
        self.lane_temp[lane] = t
        self.lane_topk[lane] = k
        self.lane_topp[lane] = p
        sp = req.sampling
        self.lane_eos[lane] = (self.eos if sp is None or sp.eos is None
                               else sp.eos)
        self._lane_prio[lane] = req.priority

    def _reset_lane_knobs(self, lane: int) -> None:
        """Back to the loop defaults when a lane frees — a stale
        sampled-lane temperature would otherwise keep the block's
        all-greedy fast path (`lax.cond` on any(temp > 0)) disabled."""
        self.lane_temp[lane] = self.temperature
        self.lane_topk[lane] = self.top_k
        self.lane_topp[lane] = self.top_p
        self.lane_eos[lane] = self.eos
        self._lane_prio[lane] = 0

    def _register_admit(self, lane: int, req: Request, bucket: int,
                        prefill_chunks: int = 1, group_size: int = 1,
                        prefix_tokens: int = 0, prefix_exact: bool = False,
                        lane_key=None):
        """Host-side bookkeeping for a request just spliced into `lane`."""
        cap = self._degrade_cap()
        budget = req.max_new if cap is None else min(req.max_new, cap)
        st_deg = cap is not None and budget < req.max_new
        self.active[lane] = budget > 0
        self.remaining[lane] = max(budget, 0)
        self.outputs[lane] = []
        self._lane_rid[lane] = req.rid
        self._set_lane_knobs(lane, req)
        if lane_key is not None:
            self._lane_keys[lane] = np.asarray(lane_key, np.uint32)
        st = self.stats[req.rid]
        st.lane = lane
        st.t_admit = self._now()
        st.bucket = bucket
        st.prefill_chunks = prefill_chunks
        st.admit_seq = self._admit_seq
        st.group_size = group_size
        st.prefix_tokens = prefix_tokens
        st.prefix_exact = prefix_exact
        st.degraded = st.degraded or st_deg
        self._admit_seq += 1
        if req.max_new <= 0:                   # prefill-only request
            st.t_first = st.t_admit            # ttft == prefill completion
            self._finish_lane(lane, self._now())

    # -- priority preemption + drain-aware reservation -----------------------

    def _admit_resumed(self, lane: int, req: Request) -> None:
        """Splice a preempted request's captured state back into a free
        lane — zero prefill work; the stream continues exactly where it
        stopped (outputs, budget, PRNG carry, and the carried next token
        all restored)."""
        self._ensure_state()
        rs = req.resume
        req.resume = None
        self.state, self.tok = _resume_fn()(
            self.state, self.tok, lane, rs.state,
            jnp.asarray(rs.tok, jnp.int32))
        self.counters["admit_dispatches"] += 1
        self.active[lane] = rs.rem > 0
        self.remaining[lane] = rs.rem
        self.outputs[lane] = list(rs.outputs)
        self._lane_rid[lane] = req.rid
        self._set_lane_knobs(lane, req)
        self._lane_keys[lane] = np.asarray(rs.key, np.uint32)
        st = self.stats[req.rid]
        st.lane = lane
        st.admit_seq = self._admit_seq
        self._admit_seq += 1

    def _preempt_lane(self, lane: int) -> None:
        """Evict one active lane for a higher class: capture its exact
        mid-stream snapshot (`_lane_slice_fn` state slice + carried next
        token + budget + PRNG carry + emitted tokens) onto the request
        and requeue it at its arrival rank."""
        rid = self._lane_rid[lane]
        req = self._req_by_rid[rid]
        fresh = _lane_slice_fn(_model_key(self.model))(self.state, lane)
        req.resume = _ResumeState(
            state=fresh, tok=int(np.asarray(self.tok)[lane]),
            rem=int(self.remaining[lane]),
            key=self._lane_keys[lane].copy(),
            outputs=list(self.outputs[lane]))
        self.active[lane] = False
        self.remaining[lane] = 0
        self.outputs[lane] = []
        self._lane_rid[lane] = None
        self._reset_lane_knobs(lane)
        st = self.stats[rid]
        st.preemptions += 1
        st.lane = -1
        self.counters["preemptions"] += 1
        self._cache_insert_preempted(req, fresh)
        self._requeue(req)

    def _cache_insert_preempted(self, req: Request, fresh) -> None:
        """Preemption-aware prefix caching: instead of idling on the
        Request until resume, the captured snapshot ALSO feeds the radix
        trie as a rows donor when its prompt prefix is still slot-aligned
        (`surgery.prefix_slot_aligned` via `cache_prefix_rows`) — a
        re-admitted sibling prompt then resumes its chunked prefill from
        the victim's rows. The gate naturally refuses decode-advanced
        captures (step > prompt length after the first emitted token) and
        quantized/latent caches, so only donors whose rows equal the
        pre-pruning workspace bit-for-bit get in; grid conditions mirror
        `_cache_insert_finalized` (prompt on the resume chunk grid, chunk
        == cfg.attn_chunk for exact f32 acc association)."""
        pc = self.prefix_cache
        if pc is None or not req.reuse_prefix:
            return
        c = self.chunk_prefill
        n = len(req.prompt)
        if not (self._rows_reuse and n % c == 0
                and c == self.model.cfg.attn_chunk):
            return
        kv = getattr(fresh, "kv", None)
        if kv is None:
            return
        # cache_prefix_rows checks alignment on the light fields
        # (fill/step/pos/valid) before pulling k/v/acc to host, so a
        # refused donor costs no heavy device->host copy
        rows = cache_prefix_rows(kv, n)
        if rows is not None:
            pc.insert_rows(req.prompt, RowsEntry(n, *rows))
            self.counters["preempt_cache_inserts"] += 1
        self._sync_cache_counters()

    def _requeue(self, req: Request) -> None:
        """Re-insert a preempted request at its arrival rank: it resumes
        as soon as its class is schedulable again (its old rank keeps it
        ahead of later arrivals in the same class)."""
        req.admitted = False
        dq = self._bucket_q.setdefault(self._qkey(req), deque())
        idx = next((i for i, r in enumerate(dq)
                    if r.arrival > req.arrival), len(dq))
        dq.insert(idx, req)
        if req not in self._arrived_fifo:      # identity compare (eq=False)
            fifo = self._arrived_fifo
            idx = next((i for i, r in enumerate(fifo)
                        if r.arrival > req.arrival), len(fifo))
            fifo.insert(idx, req)
        self._arrived_count += 1

    def _try_preempt(self) -> bool:
        """With every lane busy: if the best waiting class strictly
        outranks the lowest-priority active lane, evict that lane (ties
        broken toward the most predicted remaining work — evicting it
        frees capacity for the longest). Returns True when a lane was
        freed. Equal-priority traffic never preempts, and a lane running
        a legacy `admit()` batch (no Request to requeue) is exempt."""
        if not self._bucket_q:
            return False
        top = min(self._bucket_q)
        head = self._bucket_q[top][0]
        if (head.resume is None and self._needs_chunking(top[1])
                and self._pending is not None):
            return False          # couldn't be admitted this round anyway
        pred = self.predicted_free_blocks()
        victim: Optional[int] = None
        vrank: Tuple[int, int] = (0, 0)
        for lane in np.flatnonzero(self.active):
            lane = int(lane)
            if self._pending is not None and lane == self._pending.lane:
                continue
            rid = self._lane_rid[lane]
            if rid is None or rid not in self._req_by_rid:
                continue
            rank = (int(self._lane_prio[lane]), -pred.get(lane, 0))
            if victim is None or rank < vrank:
                victim, vrank = lane, rank
        if victim is None or -top[0] <= vrank[0]:
            return False
        self._preempt_lane(victim)
        return True

    def predicted_free_blocks(self) -> Dict[int, int]:
        """Per-active-lane drain prediction: decode blocks until the
        lane frees. The expected remaining tokens are the lane's unspent
        budget, bounded by an observed mean EOS-termination length
        (minus what the lane already emitted). The bound is CLASS-LOCAL
        first: a lane whose (priority, bucket) class has accumulated at
        least 4 EOS completions uses that class's own mean — short
        bursty and long bulk traffic stop polluting each other's
        forecasts when they mix. Below the class sample floor the
        global mean applies under the original gate (at least 4
        observed EOS overall and no fewer than budget exhaustions), so
        EOS-heavy traffic predicts earlier than its worst-case budget."""
        eos_mean = None
        if (len(self._eos_lens) >= 4
                and len(self._eos_lens) >= self._budget_done):
            eos_mean = float(np.mean(self._eos_lens))
        out: Dict[int, int] = {}
        for lane in np.flatnonzero(self.active):
            lane = int(lane)
            exp = int(self.remaining[lane])
            mean = eos_mean
            rid = self._lane_rid[lane]
            st = self.stats.get(rid) if rid is not None else None
            if st is not None:
                cell = self._eos_by_class.get((st.priority, st.bucket))
                if cell is not None and len(cell) >= 4:
                    mean = float(np.mean(cell))
            if mean is not None:
                exp = min(exp, max(1, round(mean)
                                   - len(self.outputs[lane])))
            out[lane] = max(1, math.ceil(exp / self.block))
        return out

    def _reserve(self) -> None:
        """Drain-aware pre-grouping: with every lane busy, predict which
        lanes free within `reserve_blocks` decode blocks and pop that
        many queued requests NOW, so their (grouped) admission fires the
        moment the lanes actually free instead of waiting out another
        scheduling round. Reserved requests follow the normal target
        ordering (priority class, then shortest bucket, aging bound
        included) and are admitted ahead of the queues."""
        if (not self.reserve_blocks or not self.group_admit
                or not self._bucket_q):
            return
        soon = sum(1 for b in self.predicted_free_blocks().values()
                   if b <= self.reserve_blocks)
        room = soon - len(self._reserved)
        if room <= 0:
            return
        fifo_head = self._fifo_head()
        if fifo_head is None:
            return
        target = min(self._bucket_q)
        if (-target[0] <= fifo_head.priority
                and target != self._qkey(fifo_head)
                and self._head_skips >= self.max_head_skips):
            target = self._qkey(fifo_head)     # aging kicks in
        if (self._needs_chunking(target[1])
                and self._bucket_q[target][0].resume is None):
            return          # sliced prefills reserve their own lane
        group = self._take_bucket(target, room)
        if not group:
            return
        self._head_skips = (0 if fifo_head in group
                            else self._head_skips + 1)
        self._reserved.extend(group)
        self.counters["reservations"] += len(group)

    # -- graceful degradation ------------------------------------------------

    def _effective_block(self) -> int:
        """Decode block size under the current degradation level (the
        ladder's "block" override; level 0 = the configured block). A
        smaller block both amortizes less AND tightens the decode window
        (`decode_window(fill, steps)` covers fill + steps), trading peak
        throughput for shorter admission latency and a finer-grained
        deadline/cancel/quarantine response — token values are UNCHANGED
        (block size never enters the per-lane math)."""
        if not self._degrade_level:
            return self.block
        lvl = self.degrade_ladder[self._degrade_level - 1]
        return max(1, int(lvl.get("block", self.block)))

    def _degrade_cap(self) -> Optional[int]:
        """Budget cap applied to NEW admissions at the current level
        (the ladder's "max_new_cap"; None = uncapped). Capped requests
        complete with outcome "done" and `stats.degraded=True`."""
        if not self._degrade_level:
            return None
        cap = self.degrade_ladder[self._degrade_level - 1].get(
            "max_new_cap")
        return int(cap) if cap else None

    def _pressure_tick(self) -> None:
        """The pressure controller: one hysteresis step per scheduler
        round. DOWN when every lane is busy, the waiting population is
        at least `degrade_high`, and `predicted_free_blocks()` says no
        lane frees within the reservation horizon (genuine sustained
        pressure, not a drain already in flight); UP when the waiting
        population falls to `degrade_low`. Every transition counts
        (`degrade_down`/`degrade_up` — count-class in CI)."""
        if not self.degrade_ladder:
            return
        waiting = self._arrived_count + len(self._reserved)
        if (waiting >= self.degrade_high
                and self._degrade_level < len(self.degrade_ladder)
                and not any(len(f) for f in self.shard_free_lanes())):
            pred = self.predicted_free_blocks()
            if pred and min(pred.values()) > max(1, self.reserve_blocks):
                self._degrade_level += 1
                self.counters["degrade_down"] += 1
        elif waiting <= self.degrade_low and self._degrade_level:
            self._degrade_level -= 1
            self.counters["degrade_up"] += 1

    # -- chunked (time-sliced) admission -------------------------------------

    def _needs_chunking(self, bucket: int) -> bool:
        return 0 < self.chunk_prefill < bucket

    def _start_chunked(self, lane: int, req: Request, padded: np.ndarray,
                       bucket: int):
        """Reserve `lane` and open a sliced prefill for a long prompt. Only
        the chunks that contain real tokens are ever dispatched — trailing
        all-pad chunks of the bucket contribute nothing by construction.

        The workspace is rounded up to a multiple of the chunk size so
        every dispatched slice is full-width: a ragged final slice would
        silently compile one extra program per distinct ragged width (the
        true-length mask makes the extra pad columns free).

        Prefix cache: an exact-prompt hit splices the cached finalized
        state directly (no slices, no reserved pending prefill); a rows
        hit at depth p pre-fills the workspace with the cached rows and
        resumes at chunk p/C — the remaining slices repeat the
        from-scratch accumulation bit-for-bit."""
        self._ensure_state()
        c = self.chunk_prefill
        # deepest usable donor boundary: the final chunk (the one holding
        # the last real token, whose hidden feeds the logits) always runs
        cap = ((len(req.prompt) - 1) // c) * c
        hit, rows = self._cache_match(req, rows_cap=cap)
        if hit is not None:
            self._splice_cached(lane, req, hit)
            return
        ws = math.ceil(bucket / c) * c
        if ws != bucket:
            ext = np.zeros(ws, padded.dtype)
            ext[:len(padded)] = padded
            padded = ext
        if rows is not None:
            pstate = self._resume(rows.k, rows.v, rows.acc, ws)
            base = rows.depth
            self.counters["prefix_copies"] += 1
            self.counters["prefix_tokens_reused"] += base
        else:
            pstate = self.model.init_prefill_chunk_state(1, ws)
            base = 0
        self._pending = _ChunkedPrefill(
            req=req, lane=lane, bucket=ws, padded=padded, pstate=pstate,
            n_chunks=math.ceil(len(req.prompt) / c), next_chunk=base // c,
            base=base, collect=(self._rows_reuse and req.reuse_prefix))
        self._prefill_shapes.add(("chunk", c, ws))

    def _advance_chunked(self) -> bool:
        """Run ONE prefill slice of the in-flight chunked admission (the
        caller interleaves decode blocks between slices). Returns True if
        a slice was dispatched."""
        p = self._pending
        if p is None:
            return False
        if p.req.cancelled or self._deadline_over(p.req, self._now()):
            # drop the in-flight sliced prefill: the reserved lane frees
            # immediately and the remaining slices are never dispatched
            self._pending = None
            self._resolve_dead(p.req)
            return False
        c = self.chunk_prefill
        ci = p.next_chunk
        tok_c = jnp.asarray(p.padded[ci * c:(ci + 1) * c][None])
        length = jnp.asarray([len(p.req.prompt)], jnp.int32)
        p.x_last, p.pstate = self._chunk(self.params, p.pstate, tok_c,
                                         jnp.asarray(ci * c, jnp.int32),
                                         length)
        self.counters["chunk_dispatches"] += 1
        p.next_chunk += 1
        q = p.next_chunk * c
        if p.collect and p.base < q <= (len(p.req.prompt) // c) * c:
            # host snapshot of the acc prefix at boundary q: acc columns
            # [0, q) depend only on tokens [0, q) (columns past a chunk's
            # causal reach carry exactly-zero mass), so together with the
            # write-once K/V rows this is a bit-exact resume donor for
            # ANY continuation sharing those tokens. Boundaries whose
            # chunk holds pad tokens (q > prompt length) are never taken.
            p.snap_acc.append((q, np.asarray(p.pstate.acc[:, 0, :, :q])))
        if p.next_chunk >= p.n_chunks:
            rows_kv = None
            if p.snap_acc:
                # ONE workspace K/V snapshot covers every boundary (rows
                # are write-once) — taken before finalize donates pstate
                q_max = p.snap_acc[-1][0]
                rows_kv = (np.asarray(p.pstate.k[:, 0, :, :q_max]),
                           np.asarray(p.pstate.v[:, 0, :, :q_max]))
            logits, fresh = self._finalize(
                self.params, p.pstate, p.x_last,
                jnp.asarray((p.n_chunks - 1) * c, jnp.int32), length)
            self.counters["prefill_dispatches"] += 1
            self._pending = None
            self._splice(p.lane, p.req, logits[0], fresh, bucket=p.bucket,
                         prefill_chunks=p.n_chunks, prefix_tokens=p.base)
            # trie insertion AFTER the splice: admission latency (ttft)
            # never pays for the host copies; fresh/logits survive the
            # splice (only state/tok are donated)
            self._cache_insert_chunked(p, logits[0], fresh, rows_kv)
        return True

    def _cache_insert_chunked(self, p: _ChunkedPrefill, logits, fresh,
                              rows_kv):
        """Insert a finished sliced prefill: the finalized state at the
        full prompt, plus one rows donor per collected chunk boundary
        (each boundary needs its own acc copy — columns keep absorbing
        mass from later query rows, so acc is only valid at the exact
        boundary it was snapped at)."""
        pc = self.prefix_cache
        if pc is None or not p.req.reuse_prefix:
            return
        tokens = np.asarray(p.req.prompt)
        pc.insert_state(tokens, StateEntry(
            length=len(tokens), bucket=p.bucket, logits=np.asarray(logits),
            state=jax.tree.map(np.asarray, fresh)))
        if rows_kv is not None:
            k_all, v_all = rows_kv                     # [L, Hk, q_max, dh]
            for q, acc_q in p.snap_acc:
                pc.insert_rows(tokens[:q], RowsEntry(
                    q, k_all[:, :, :q].copy(), v_all[:, :, :q].copy(),
                    acc_q))
        self._sync_cache_counters()

    def schedule(self) -> int:
        """Admit queued, already-arrived requests into free lanes.

        Grouped admission (default): each round gathers up to
        len(free_lanes) arrived requests that pad to one shared bucket
        and admits them with a single batched prefill + multi-lane
        splice. The target bucket is the FIFO head's off load; under
        load (more arrived requests than free lanes) it is the SHORTEST
        bucket present, so short prompts are not starved behind long
        ones — bounded by AGING: after the FIFO head has been passed
        over `max_head_skips` rounds in a row, its bucket is forced, so
        a long prompt can never starve indefinitely under sustained
        short-prompt overload. Requests sharing a bucket keep FIFO order
        within it. Long prompts (bucket > chunk_prefill) open a
        time-sliced prefill on a reserved lane instead of blocking on a
        whole-prompt dispatch; at most one sliced prefill is in flight
        at a time — while one is, a chunk-needing target falls back to
        the shortest chunk-free bucket (aging credit untouched) so free
        lanes never idle behind the sliced prefill.

        Priority classes sort ahead of bucket width: the target class is
        the best (-priority, bucket) tuple present, so higher classes
        always admit first and equal-priority traffic reduces exactly to
        the bucket ordering above. With NO free lane, a strictly-higher
        waiting class may preempt the lowest-priority active lane
        (`_try_preempt`); otherwise drain-aware reservation pre-pops the
        requests predicted to fit within `reserve_blocks` decode blocks
        (`_reserve`) so their grouped prefill fires the moment lanes
        free. A preempted request resumes via a zero-prefill state
        splice (`_admit_resumed`), always solo, never chunked.

        Each round is O(newly arrived + len(buckets)): requests whose
        arrival passed are drained once into their bucket's FIFO deque,
        the target bucket comes from the deque heads, and the group is
        popped from one deque — never a scan over the arrived backlog.

        Under a lane mesh admission is SHARD-AWARE: the scheduler tracks
        free lanes per shard (`shard_free_lanes`) and each round admits
        into ONE shard's lane rows — the shard with the most free lanes
        (lowest index on ties) — so a grouped prefill's `lanes_insert`
        splice and the subsequent `write_token_stacked` scatters stay
        shard-local; the loop covers the remaining shards on its next
        iterations. When preemption frees a lane, the next round's
        most-free shard IS the victim's shard, so the admission lands on
        the lane that was freed for it. A 1-shard engine reduces exactly
        to the unsharded free-lane list.
        """
        n = 0
        while True:
            self._drain_arrivals(self._now())
            if self._arrived_count == 0 and not self._reserved:
                break
            free = max(self.shard_free_lanes(), key=len)
            if not free:
                if self._try_preempt():
                    continue
                self._reserve()
                break
            if self._reserved:
                group = self._take_reserved(len(free))
                self.counters["reserved_admits"] += len(group)
                n += self._admit_chosen(free, group)
                continue
            fifo_head = self._fifo_head()      # arrived_count > 0 ⇒ set
            if not self.group_admit:
                target, take = self._qkey(fifo_head), 1
            else:
                best = min(self._bucket_q)     # best class, shortest bucket
                if self._arrived_count > len(free):
                    target = best
                    if (-best[0] <= fifo_head.priority
                            and target != self._qkey(fifo_head)
                            and self._head_skips >= self.max_head_skips):
                        target = self._qkey(fifo_head)  # aging kicks in
                else:                          # off load: FIFO head, unless
                    target = self._qkey(fifo_head)      # a class outranks it
                    if -best[0] > fifo_head.priority:
                        target = best
                take = len(free)
            if self._bucket_q[target][0].resume is not None:
                # preempted request resuming: zero-prefill solo splice
                req = self._take_bucket(target, 1)[0]
                if self._resolve_dead(req):
                    continue
                self._head_skips = (0 if fifo_head is req
                                    else self._head_skips + 1)
                self._admit_resumed(free[0], req)
                n += 1
                continue
            if (self.group_admit and self._pending is not None
                    and self._needs_chunking(target[1])):
                # one sliced prefill at a time — instead of idling the
                # free lanes behind it, admit the shortest chunk-free
                # bucket this round (resume heads are chunk-free by
                # construction); the head's aging credit is NOT touched
                # on a blocked round, so the max_head_skips bound keeps
                # holding
                alts = [k for k in self._bucket_q
                        if not self._needs_chunking(k[1])
                        or self._bucket_q[k][0].resume is not None]
                if not alts:
                    break
                target = min(alts)
                if self._bucket_q[target][0].resume is not None:
                    req = self._take_bucket(target, 1)[0]
                    if self._resolve_dead(req):
                        continue
                    self._admit_resumed(free[0], req)
                    n += 1
                    continue
            if self._needs_chunking(target[1]):
                if self._pending is not None:
                    break                      # one sliced prefill at a time
                # aging accounting: `is`/`in` are identity comparisons
                # (Request eq=False); only rounds that ADMIT something
                # consume or earn credit
                head = self._take_bucket(target, 1)[0]
                if self._resolve_dead(head):
                    continue
                self._head_skips = (0 if fifo_head is head
                                    else self._head_skips + 1)
                self._start_chunked(free[0], head,
                                    self._padded_prompt(head)[0],
                                    head.bucket)
                continue
            group = self._take_bucket(target, take)
            self._head_skips = (0 if fifo_head in group
                                else self._head_skips + 1)
            n += self._admit_chosen(free, group)
        return n

    def _admit_chosen(self, free: List[int], group: List[Request]) -> int:
        """Dispatch an already-popped admission group into free lanes
        (resume-aware: a captured-state head splices without prefill).
        Cancelled / deadline-expired members resolve here instead of
        being admitted — the group shrinks, never the dispatch count."""
        group = [r for r in group if not self._resolve_dead(r)]
        if not group:
            return 0
        if group[0].resume is not None:
            self._admit_resumed(free[0], group[0])
        elif len(group) == 1:
            self._admit_lane(free[0], group[0])
        else:
            self._admit_group(free[:len(group)], group)
        return len(group)

    # -- shard accounting ----------------------------------------------------

    def _shard_of(self, lane: int) -> int:
        """Shard owning `lane`: the P("data") layout gives each shard a
        contiguous block of lanes_per_shard lane rows."""
        return lane // self.lanes_per_shard

    def shard_free_lanes(self) -> List[List[int]]:
        """Free (admittable) lanes grouped by shard — the scheduler's
        shard-local admission view. A pending sliced prefill's reserved
        lane is excluded, same as the unsharded free-lane rule. Without
        a mesh this is a single list (shards == 1).

        A chaos shard BLACKOUT hides that shard's free lanes here (a
        brownout: resident lanes keep decoding, no NEW work lands) —
        admission routes around it through the most-free-shard rule and
        the round counter guarantees it expires (`run()` keeps ticking
        rounds even when nothing else progresses)."""
        free: List[List[int]] = [[] for _ in range(self.shards)]
        for lane in np.flatnonzero(~self.active):
            lane = int(lane)
            if self._pending is not None and lane == self._pending.lane:
                continue
            free[self._shard_of(lane)].append(lane)
        if self.chaos is not None and self.chaos.blackout_shard >= 0:
            black = False
            for s in range(self.shards):
                if self.chaos.blacked_out(self._rounds, s):
                    black = True
                    free[s] = []
            if black and not self._blackout_on:
                self.counters["chaos_blackouts"] += 1
            self._blackout_on = black
        return free

    def _blackout_active(self) -> bool:
        return (self.chaos is not None
                and self.chaos.blackout_shard >= 0
                and any(self.chaos.blacked_out(self._rounds, s)
                        for s in range(self.shards)))

    def admit(self, prompts: np.ndarray):
        """Deprecated legacy all-lanes admission: prompts
        [lanes, prompt_len] are prefilled in one batch (one compile, no
        lane splicing) and every lane restarts with the shared `max_new`
        budget. Submit `Request`s and `run()` instead."""
        warnings.warn(
            "ServeLoop.admit() is deprecated; submit(Request(...)) per "
            "request and drive with run()",
            DeprecationWarning, stacklevel=2)
        if self._t0 is None:
            self._t0 = time.monotonic()
        batch = {"tokens": jnp.asarray(prompts)}
        logits, self.state = self._prefill(self.params, batch)
        self.counters["prefill_dispatches"] += 1
        # same next-token rule as lane admission: sampling (when enabled)
        # covers the first generated token on this path too
        self.tok = _next_token(logits, self._sample_key(), self.temperature,
                               self.top_k, self.top_p).astype(jnp.int32)
        # broadcast the engine-wide scalars through the per-lane runtime
        # slots so the vectorized block serves the deprecated surface too
        if self.temperature > 0:
            self._key, *subs = jax.random.split(self._key, self.lanes + 1)
            self._lane_keys = np.stack(
                [np.asarray(s, np.uint32) for s in subs])
        else:
            self._lane_keys = np.broadcast_to(
                np.asarray(self._key, np.uint32), (self.lanes, 2)).copy()
        self.lane_temp[:] = self.temperature
        self.lane_topk[:] = self.top_k
        self.lane_topp[:] = self.top_p
        self.lane_eos[:] = self.eos
        self._lane_prio[:] = 0
        self.active[:] = self.max_new > 0
        self.remaining[:] = max(self.max_new, 0)
        self.outputs = [[] for _ in range(self.lanes)]
        now = self._now()
        for lane in range(self.lanes):
            rid = self._next_rid
            self._next_rid += 1
            self._lane_rid[lane] = rid
            self.stats[rid] = RequestStats(
                rid, prompts.shape[1], self.max_new, lane=lane,
                t_arrival=now, t_admit=now, bucket=prompts.shape[1])

    # -- decode --------------------------------------------------------------

    def step(self) -> bool:
        """Deprecated: one decode step over all lanes; returns True while
        any lane is live. Drive the engine with `run()` instead."""
        warnings.warn(
            "ServeLoop.step() is deprecated; drive the engine with run()",
            DeprecationWarning, stacklevel=2)
        return self._step_block(1)

    def _decode_window(self, steps: int) -> Optional[int]:
        """Slot window for the next decode block: the smallest pow2 prefix
        covering every ACTIVE lane's fill plus the block's appends (None =
        full width). Inactive lanes may overflow the window; their writes
        are dropped in-device by `lane_select` and their outputs masked,
        so only active-lane coverage matters for bit-exactness."""
        if self.window != "auto" or self.state is None \
                or self.state.kv is None or not self.active.any():
            return None
        from repro.core.cache import decode_window
        fill = np.asarray(self.state.kv.fill)          # [L, lanes]
        max_fill = int(fill[:, self.active].max())
        return decode_window(max_fill, steps, self.model.decode_slots,
                             self.model.prune, grid=self.window_grid)

    def step_block(self, steps: int = 0) -> bool:
        """Deprecated public alias of the engine's decode block; `run()`
        drives the same internals without the warning."""
        warnings.warn(
            "ServeLoop.step_block() is deprecated; drive the engine with "
            "run()", DeprecationWarning, stacklevel=2)
        return self._step_block(steps)

    def _step_block(self, steps: int = 0) -> bool:
        """Decode `steps` (default: self.block) tokens in one dispatch.

        Finished lanes stop writing in-device; the host side consumes the
        (token, emitted) pairs with vectorized numpy — no per-token loop.
        Each block dispatches over the fill-covering slot window (see
        `_decode_window`), so step cost tracks the live context.

        Under degradation the default block size follows the ladder
        (`_effective_block`); with a `ChaosConfig` attached, stalls
        sleep before the dispatch and the deterministic per-block fault
        mask rides in as a runtime array (an all-zeros mask is always
        passed, so the chaos path and the clean path share ONE compiled
        program). Lanes flagged by the in-device non-finite sentinel
        are quarantined and their requests retried (`_quarantine_lane`).
        """
        steps = steps or self._effective_block()
        if self.state is None or not self.active.any():
            return bool(self.active.any())
        window = self._decode_window(steps)
        self._windows.add(window)
        self.counters["decode_windows"] = len(self._windows)
        fn = _lanes_block_fn(_model_key(self.model), steps, window,
                             self.mesh)
        was_active = self.active.copy()
        blk = self.counters["decode_blocks"]
        if self.chaos is not None and self.chaos.any_faults:
            stall = self.chaos.stall(blk)
            if stall > 0:
                self.counters["chaos_stalls"] += 1
                time.sleep(stall)
            fault = self.chaos.fault_mask(blk, steps, self.lanes)
            self.counters["chaos_faults"] += int(fault.sum())
        else:
            fault = np.zeros((steps, self.lanes), bool)
        if self.mesh is None:
            def put(a, dtype=None):
                return jnp.asarray(a, dtype)
            fault_dev = jnp.asarray(fault)
        else:
            # commit every host-side lane array to the P("data") layout
            # (and re-pin the state after any admission splice) so the
            # shard_map'd block never inserts input reshards
            self._pin_state()
            lane_sh = self._lane_sharding()

            def put(a, dtype=None):
                return jax.device_put(np.asarray(a, dtype), lane_sh)
            from jax.sharding import NamedSharding, PartitionSpec as P
            fault_dev = jax.device_put(
                fault, NamedSharding(self.mesh, P(None, "data")))
        t_disp = time.monotonic()
        (self.state, self.tok, active, rem, keys, poison, toks,
         emitted) = fn(
            self.params, self.state, self.tok,
            put(self.active), put(self.remaining),
            put(self.lane_eos, np.int32),
            put(self._lane_keys, np.uint32),
            put(self.lane_temp, np.float32),
            put(self.lane_topk, np.int32),
            put(self.lane_topp, np.float32),
            fault_dev)
        self._lane_keys = np.asarray(keys).astype(np.uint32)
        self.counters["decode_blocks"] += 1
        # knob values ride in as [lanes] arrays, so the jit cache holds ONE
        # program per (steps, window) regardless of the knob mix on board
        self.counters["decode_block_programs"] = fn._cache_size()
        host_toks = np.asarray(toks)                       # [steps, lanes]
        host_emit = np.asarray(emitted)                    # [steps, lanes]
        host_poison = np.asarray(poison)                   # [lanes]
        # per-block wall seconds (host-sync included): feeds the
        # backpressure retry_after hint; an EMA so one noisy block
        # doesn't swing the estimate
        dt = time.monotonic() - t_disp
        self._block_s_ema = (dt if self._block_s_ema is None
                             else 0.8 * self._block_s_ema + 0.2 * dt)
        self.active = np.asarray(active).copy()
        self.remaining = np.asarray(rem).astype(np.int32)
        # per-shard emission accounting (host-side — the ONLY cross-shard
        # traffic the sharded engine has)
        self._shard_tokens += host_emit.sum(axis=0).reshape(
            self.shards, self.lanes_per_shard).sum(axis=1)
        now = self._now()
        for lane in np.flatnonzero(host_emit.any(axis=0)):
            lane = int(lane)
            new = host_toks[host_emit[:, lane], lane].tolist()
            if not self.outputs[lane]:
                rid = self._lane_rid[lane]
                if rid is not None:
                    self.stats[rid].t_first = now
            self.outputs[lane].extend(new)
        # poisoned lanes never take the normal EOS/budget finish path —
        # they are quarantined and their requests retried
        for lane in np.flatnonzero(was_active & ~self.active
                                   & ~host_poison):
            self._finish_lane(int(lane), now)
        for lane in np.flatnonzero(host_poison & was_active):
            self._quarantine_lane(int(lane), now)
        return bool(self.active.any())

    def _quarantine_lane(self, lane: int, now: float) -> None:
        """One lane tripped the non-finite sentinel: free it (its state
        rows are garbage but fully overwritten by the next admission's
        splice) and retry the request by FULL deterministic replay —
        requeued at its arrival rank, re-prefilled from the prompt, with
        its memoized admission seed (`_seed_keys`) so greedy AND
        seeded-sampled streams come back token-identical. Partial tokens
        from the poisoned incarnation are discarded (the replay re-emits
        them). After `max_retries` quarantines the request resolves with
        outcome "failed", keeping the clean partial stream."""
        rid = self._lane_rid[lane]
        self.counters["quarantined_lanes"] += 1
        partial = list(self.outputs[lane])
        self.active[lane] = False
        self.remaining[lane] = 0
        self.outputs[lane] = []
        self._lane_rid[lane] = None
        self._reset_lane_knobs(lane)
        req = self._req_by_rid.get(rid) if rid is not None else None
        st = self.stats.get(rid) if rid is not None else None
        if req is None:
            # legacy admit() batch — no Request to replay
            if st is not None and rid not in self._finished:
                self.counters["failed_requests"] += 1
                st.tokens = partial
                st.outcome = "failed"
                st.detail = "non-finite logits (legacy lane: no retry)"
                st.t_done = now
                if st.t_first < st.t_admit:
                    st.t_first = now
                st.occupancy = self._lane_occupancy(lane)
                self.completed.append(st)
                self.done.append(st.tokens)
                self._finished.add(rid)
            return
        req.retries += 1
        st.retries = req.retries
        st.lane = -1
        if req.retries > self.max_retries:
            self.counters["failed_requests"] += 1
            st.tokens = partial                # keep the clean prefix
            self._finish_queued(req, "failed",
                                "non-finite logits; max_retries="
                                f"{self.max_retries} exhausted")
        else:
            self.counters["retried_requests"] += 1
            self._requeue(req)

    def _finish_lane(self, lane: int, now: float, outcome: str = "done",
                     detail: str = ""):
        rid = self._lane_rid[lane]
        if rid is None:
            return
        st = self.stats[rid]
        if st.t_first < st.t_admit:
            # nothing was ever emitted (e.g. the very first generated token
            # was EOS, which is a stop signal, not an output) — anchor ttft
            # at completion so it can never go negative
            st.t_first = now
        st.tokens = list(self.outputs[lane])
        st.t_done = now
        st.outcome = outcome
        st.detail = detail
        st.occupancy = self._lane_occupancy(lane)
        self.completed.append(st)
        self.done.append(st.tokens)
        self._finished.add(rid)
        self._lane_rid[lane] = None
        self._req_by_rid.pop(rid, None)
        self._reset_lane_knobs(lane)
        if st.max_new > 0 and outcome == "done":
            # drain-prediction statistics — natural completions only: a
            # cancelled/expired lane still has budget left and would
            # otherwise masquerade as a (short) EOS sample
            if self.remaining[lane] > 0:
                self._eos_lens.append(len(st.tokens))
                # class-local sample for predicted_free_blocks: EOS
                # lengths cluster by traffic class, not globally
                self._eos_by_class.setdefault(
                    (st.priority, st.bucket), []).append(len(st.tokens))
            else:
                self._budget_done += 1

    def _lane_occupancy(self, lane: int) -> float:
        kv = self.state.kv if self.state is not None else None
        if kv is None:
            return 0.0
        fill = np.asarray(kv.fill)                         # [L, lanes]
        return float(fill[:, lane].mean() / kv.slots)

    # -- driver ---------------------------------------------------------------

    def run(self) -> List[RequestStats]:
        """Drive until the queue is drained and every lane is idle. Each
        iteration (a scheduler ROUND) sweeps deadlines/cancellations off
        the active lanes, admits, ticks the pressure controller, then
        interleaves (at most) one prefill slice with one decode block,
        so live lanes keep emitting tokens while a long prompt prefills.

        The loop is hang-proof by construction: a round that makes NO
        progress (nothing admitted, sliced, or decoded) with waiting
        work, idle lanes, and nothing due to arrive can only mean the
        scheduler cannot place what is queued — after a few such rounds
        the stuck requests resolve to structured rejections
        (`_fail_stuck`) instead of spinning forever. A chaos blackout is
        exempted (it expires with the round counter)."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        idle = 0
        while (self._arrived_count or self._arrivals or self._reserved
               or self.active.any() or self._pending is not None):
            self._rounds += 1
            self._sweep_lanes(self._now())
            admitted = self.schedule()
            self._pressure_tick()
            stepped = self._advance_chunked()
            if self.active.any():
                self._step_block()
            elif stepped or admitted:
                pass
            else:
                # never sleep out the arrival timer of a cancelled
                # future arrival — resolve it now
                while self._arrivals and self._arrivals[0].cancelled:
                    self._resolve_dead(self._arrivals.popleft())
                if self._arrivals:
                    wait = self._arrivals[0].arrival - self._now()
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                elif self._blackout_active():
                    time.sleep(0.001)   # rounds tick; the blackout expires
                elif self._arrived_count or self._reserved:
                    idle += 1
                    if idle >= 3:
                        self._fail_stuck()
                        idle = 0
                continue
            idle = 0
        return self.completed

    def _fail_stuck(self) -> None:
        """Last-resort hang breaker: rounds make zero progress while
        requests wait, lanes idle, and nothing is pending or arriving —
        the scheduler cannot place the waiting work (an un-admittable
        shape that slipped past submit validation, or a scheduler bug).
        Resolve every waiting request as a structured rejection instead
        of looping forever."""
        stuck: List[Request] = list(self._reserved)
        self._reserved.clear()
        for key in list(self._bucket_q):
            dq = self._bucket_q.pop(key)
            self._arrived_count -= len(dq)
            stuck.extend(dq)
        for req in stuck:
            self.counters["rejected_requests"] += 1
            self._finish_queued(req, "rejected",
                                "scheduler made no progress — request "
                                "cannot be placed")

    def prefill_programs(self) -> Dict[str, int]:
        """Compile accounting for the prefill path.

        `loop_shapes`: distinct prefill shapes THIS loop dispatched (what a
        bounded bucket grid guarantees). `jit_cache`: entries in the
        process-wide jit caches backing this model's prefill/chunk/finalize
        entry points (shared across ServeLoops of functionally identical
        models — the actual number of compiled XLA programs)."""
        jit_cache = sum(fn._cache_size()
                        for fn in (self._prefill_one, self._prefill_group,
                                   self._chunk, self._finalize)
                        if hasattr(fn, "_cache_size"))
        return {"loop_shapes": len(self._prefill_shapes),
                "jit_cache": int(jit_cache)}

    def aggregate(self) -> Dict[str, Any]:
        """Serving metrics over completed requests (+ dispatch counters;
        the string-valued `donation` marker passes through unchanged).

        With a prefix cache enabled, adds `prefix_hit_rate`
        (hits / admission lookups), `prefix_dedup_ratio` (prompt tokens
        served from cache / prompt tokens of completed requests — the
        fraction of prefill work deduplicated), and the trie's live
        bytes/entries/insert/eviction tallies."""
        counters = {k: (v if isinstance(v, str) else float(v))
                    for k, v in self.counters.items()}
        prefix: Dict[str, float] = {}
        if self.prefix_cache is not None:
            self._sync_cache_counters()
            counters.update({k: float(v) for k, v in
                             self.prefix_cache.stats().items()})
            lookups = self.counters["prefix_lookups"]
            prefix["prefix_hit_rate"] = (
                self.counters["prefix_hits"] / lookups if lookups else 0.0)
            prompt_toks = sum(s.prompt_len for s in self.completed)
            prefix["prefix_dedup_ratio"] = (
                sum(s.prefix_tokens for s in self.completed) / prompt_toks
                if prompt_toks else 0.0)
        if not self.completed:
            return {"requests": 0.0, "tokens": 0.0, "wall_s": 0.0,
                    "tokens_per_s": 0.0, "mean_latency_s": 0.0,
                    "mean_occupancy": 0.0, "p50_ttft_s": 0.0,
                    "p99_ttft_s": 0.0, "prefill_programs": 0.0,
                    **counters, **prefix}
        toks = sum(len(s.tokens) for s in self.completed)
        t_end = max(s.t_done for s in self.completed)
        t_begin = min(s.t_arrival for s in self.completed)
        wall = max(t_end - t_begin, 1e-9)
        ttfts = [s.ttft for s in self.completed]
        shard_rows: Dict[str, float] = {}
        if self.shards > 1:
            # per-shard throughput + the dispatch-normalized rate the
            # scaling acceptance row is built on: wall-clock cannot scale
            # on forced host devices, tokens per decode-block dispatch can
            blocks = max(self.counters["decode_blocks"], 1)
            shard_rows["shards"] = float(self.shards)
            for i, t in enumerate(self._shard_tokens):
                shard_rows[f"shard{i}_tokens"] = float(t)
                shard_rows[f"shard{i}_tok_s"] = float(t) / wall
            shard_rows["tokens_per_dispatch"] = (
                float(self._shard_tokens.sum()) / blocks)
        return {
            **counters,
            "requests": float(len(self.completed)),
            "tokens": float(toks),
            "wall_s": wall,
            "tokens_per_s": toks / wall,
            "mean_latency_s": float(np.mean([s.latency
                                             for s in self.completed])),
            "mean_occupancy": float(np.mean([s.occupancy
                                             for s in self.completed])),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
            "prefill_programs": float(len(self._prefill_shapes)),
            **shard_rows,
            **prefix,
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="unicaim",
                    choices=["unicaim", "h2o", "streaming", "dense"])
    ap.add_argument("--fused", action="store_true",
                    help="single-pass fused decode engine (unicaim only)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-token Python loop instead of lax.scan")
    ap.add_argument("--serve", action="store_true",
                    help="continuous-batching demo: 2x batch staggered "
                         "variable-length requests through ServeLoop")
    ap.add_argument("--chunk-prefill", type=int, default=0,
                    help="slice prefills into this many tokens per "
                         "dispatch, interleaved with decode (--serve only)")
    ap.add_argument("--prefix-cache", type=int, default=0, metavar="BYTES",
                    help="radix-trie prefix cache byte budget (0 = off; "
                         "--serve only)")
    ap.add_argument("--no-buckets", action="store_true",
                    help="legacy exact-length prefills (one compile per "
                         "distinct prompt length)")
    ap.add_argument("--sequential-admit", action="store_true",
                    help="disable grouped admission (one prefill + splice "
                         "dispatch per request; --serve only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for the scanned decode "
                         "block (0 = greedy; --serve only)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="truncate sampling to the k most likely tokens "
                         "(0 = full distribution; --serve only)")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling: truncate to the smallest "
                         "token set with cumulative probability >= p "
                         "(0 = disabled; --serve only)")
    ap.add_argument("--no-window", action="store_true",
                    help="always decode at full slot width instead of "
                         "the fill-covering pow2 window (--serve only)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    budget = max(64, args.prompt_len // 2)
    if args.policy == "unicaim":
        prune = baselines.unicaim(heavy=budget, reserve=64,
                                  select_k=max(16, budget // 8),
                                  fused=args.fused)
    elif args.policy == "h2o":
        prune = baselines.h2o(heavy=budget, reserve=64)
    elif args.policy == "streaming":
        prune = baselines.streaming(budget + 64)
    else:
        prune = baselines.dense(args.prompt_len + args.new_tokens)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if args.serve:
        loop = ServeLoop(model, params, lanes=args.batch,
                         max_new=args.new_tokens, block=8,
                         buckets=None if args.no_buckets else "auto",
                         chunk_prefill=args.chunk_prefill,
                         group_admit=not args.sequential_admit,
                         temperature=args.temperature, top_k=args.top_k,
                         top_p=args.top_p,
                         window=None if args.no_window else "auto",
                         prefix_cache_bytes=args.prefix_cache)
        lens = (args.prompt_len, max(8, args.prompt_len // 2),
                max(8, args.prompt_len - 7), max(8, args.prompt_len // 3))
        for i in range(2 * args.batch):
            loop.submit(Request(
                prompt=rng.integers(0, cfg.vocab_size, lens[i % len(lens)]),
                max_new=args.new_tokens // (1 + i % 2)))
        t0 = time.time()
        stats = loop.run()
        dt = time.time() - t0
        agg = loop.aggregate()
        for s in stats:
            print(f"  req {s.rid}: lane={s.lane} prompt={s.prompt_len} "
                  f"bucket={s.bucket} chunks={s.prefill_chunks} "
                  f"new={len(s.tokens)} latency={s.latency:.2f}s "
                  f"ttft={s.ttft:.2f}s occ={s.occupancy:.2f}")
        print(f"arch={cfg.name} policy={args.policy} fused={args.fused} "
              f"served {len(stats)} reqs on {args.batch} lanes in {dt:.2f}s "
              f"({agg['tokens_per_s']:.1f} tok/s, "
              f"p99_ttft={agg['p99_ttft_s']:.2f}s, "
              f"{loop.prefill_programs()['loop_shapes']} prefill shapes, "
              f"{loop.counters['prefill_dispatches']} prefill + "
              f"{loop.counters['admit_dispatches']} admit dispatches, "
              f"{loop.counters['grouped_requests']} reqs group-admitted)")
        if loop.prefix_cache is not None:
            print(f"prefix cache: hit_rate={agg['prefix_hit_rate']:.2f} "
                  f"dedup={agg['prefix_dedup_ratio']:.2f} "
                  f"{int(agg['prefix_cache_bytes'])} bytes, "
                  f"{int(agg['prefix_cache_entries'])} entries, "
                  f"{loop.counters['prefix_evictions']} evictions")
        return

    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts)}
    t0 = time.time()
    if args.no_scan:
        toks, _ = greedy_generate(model, params, batch, args.new_tokens)
    else:
        toks, _ = generate_scan(model, params, batch, args.new_tokens)
    toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    mode = "loop" if args.no_scan else "scan"
    print(f"arch={cfg.name} policy={args.policy} mode={mode} "
          f"fused={args.fused} cache_slots={prune.slots} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
