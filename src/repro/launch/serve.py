"""Serving driver: batched prefill + decode with the UniCAIM cache.

Implements a slot-based continuous-batching loop: a fixed number of decode
lanes; finished sequences free their lane for the next queued request. The
per-step work is one jitted `decode_step` over the whole lane batch — the
paper's target regime (memory-bound autoregressive decoding).
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model


def greedy_generate(model: Model, params, batch, steps: int,
                    temperature: float = 0.0, key=None):
    """Prefill + `steps` decode steps. Returns [B, steps] generated ids."""
    logits, state = jax.jit(model.prefill)(params, batch)
    decode = jax.jit(model.decode_step)
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(steps):
        toks.append(tok)
        logits, state = decode(params, state, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, -1)
    return jnp.stack(toks, axis=1), state


def generate_scan(model: Model, params, batch, steps: int):
    """lax.scan'd decode loop (single dispatch; production serving path)."""
    logits, state = model.prefill(params, batch)
    tok0 = jnp.argmax(logits, -1)

    def body(carry, _):
        state, tok = carry
        logits, state = model.decode_step(params, state, tok)
        nxt = jnp.argmax(logits, -1)
        return (state, nxt), tok

    (state, _), toks = jax.lax.scan(body, (state, tok0), None, length=steps)
    return toks.swapaxes(0, 1), state


class ServeLoop:
    """Minimal continuous batching: fixed decode lanes + request queue."""

    def __init__(self, model: Model, params, lanes: int, prompt_len: int,
                 max_new: int = 64, eos: int = -1):
        self.model = model
        self.params = params
        self.lanes = lanes
        self.max_new = max_new
        self.eos = eos
        self.prompt_len = prompt_len
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.state = None
        self.remaining = np.zeros(lanes, np.int64)
        self.outputs: List[List[int]] = [[] for _ in range(lanes)]
        self.done: List[List[int]] = []
        self.tok = None

    def admit(self, prompts: np.ndarray):
        """prompts: [lanes, prompt_len] — (re)fill all lanes at once."""
        batch = {"tokens": jnp.asarray(prompts)}
        logits, self.state = self._prefill(self.params, batch)
        self.tok = jnp.argmax(logits, -1)
        self.remaining[:] = self.max_new
        self.outputs = [[] for _ in range(self.lanes)]

    def step(self) -> bool:
        """One decode step over all lanes; returns True while any lane live."""
        if self.state is None or not (self.remaining > 0).any():
            return False
        logits, self.state = self._decode(self.params, self.state, self.tok)
        nxt = jnp.argmax(logits, -1)
        host = np.asarray(self.tok)
        for i in range(self.lanes):
            if self.remaining[i] > 0:
                self.outputs[i].append(int(host[i]))
                self.remaining[i] -= 1
                if host[i] == self.eos:
                    self.remaining[i] = 0
        self.tok = nxt
        return bool((self.remaining > 0).any())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="unicaim",
                    choices=["unicaim", "h2o", "streaming", "dense"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    budget = max(64, args.prompt_len // 2)
    if args.policy == "unicaim":
        prune = baselines.unicaim(heavy=budget, reserve=64,
                                  select_k=max(16, budget // 8))
    elif args.policy == "h2o":
        prune = baselines.h2o(heavy=budget, reserve=64)
    elif args.policy == "streaming":
        prune = baselines.streaming(budget + 64)
    else:
        prune = baselines.dense(args.prompt_len + args.new_tokens)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts)}
    t0 = time.time()
    toks, _ = greedy_generate(model, params, batch, args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} policy={args.policy} cache_slots={prune.slots} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
