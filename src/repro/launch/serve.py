"""Serving driver: lane-granular continuous batching over the UniCAIM cache.

The engine keeps a fixed number of decode *lanes* (batch slots) and a
request queue. Each request carries its own prompt (arbitrary length ≤ max)
and `max_new` budget; it is prefilled on its own (`Model.prefill_one`) and
spliced into a free lane of the live batched `DecodeState`
(`transformer.lane_insert`) without disturbing the other lanes. Decode runs
as a single jitted multi-step `lax.scan` over the whole lane batch — one
dispatch per block of tokens — with the state donated so XLA updates it in
place.

Termination is **in-device**: an `active` lane mask rides through the
scanned block, finished lanes stop contributing state writes, and the block
returns per-step (token, emitted) pairs so the host bookkeeping is
vectorized numpy instead of a per-token/per-lane Python loop. A lane that
hits EOS or its budget is freed and refilled from the queue mid-flight —
the fixed-budget cache (the paper's point) stays busy under realistic
mixed traffic. This is the paper's target regime: memory-bound
autoregressive decoding where per-token Python dispatch otherwise
dominates the step time.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model, lane_insert, lane_select


def greedy_generate(model: Model, params, batch, steps: int,
                    temperature: float = 0.0, key=None):
    """Prefill + `steps` decode steps. Returns [B, steps] generated ids.

    One Python dispatch per token — the reference loop (and the only one
    that supports sampling); production serving uses the scanned paths.
    """
    logits, state = jax.jit(model.prefill)(params, batch)
    decode = jax.jit(model.decode_step)
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(steps):
        toks.append(tok)
        logits, state = decode(params, state, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, -1)
    return jnp.stack(toks, axis=1), state


def decode_block(model: Model, params, state, tok, steps: int):
    """`steps` greedy decode steps as one lax.scan (pure, traceable).

    tok: [B] current token → (state, next_tok [B], toks [steps, B]) where
    toks[0] == tok (the scan emits, then advances — same order as the
    per-token loop).
    """
    def body(carry, _):
        state, tok = carry
        logits, state = model.decode_step(params, state, tok)
        nxt = jnp.argmax(logits, -1)
        return (state, nxt), tok

    (state, tok), toks = jax.lax.scan(body, (state, tok), None, length=steps)
    return state, tok, toks


def decode_block_masked(model: Model, params, state, tok, active, rem,
                        steps: int, eos: int):
    """`steps` greedy decode steps with in-device per-lane termination.

    active: [B] bool lane-live mask; rem: [B] int32 remaining budget.
    Each step emits the carried token for active lanes, then advances; a
    lane deactivates after emitting EOS (if eos >= 0) or exhausting its
    budget, and from then on its state is frozen (lane_select drops its
    writes) while the other lanes keep decoding. Returns
    (state, tok, active, rem, toks [steps, B], emitted [steps, B]).
    """
    def body(carry, _):
        state, tok, active, rem = carry
        logits, new_state = model.decode_step(params, state, tok)
        state = lane_select(active, new_state, state)
        emit = active & (rem > 0)      # robust to active lanes w/o budget
        rem = rem - emit.astype(rem.dtype)
        active = emit if eos < 0 else emit & (tok != eos)
        active = active & (rem > 0)
        nxt = jnp.argmax(logits, -1).astype(tok.dtype)
        return (state, nxt, active, rem), (tok, emit)

    (state, tok, active, rem), (toks, emitted) = jax.lax.scan(
        body, (state, tok, active, rem), None, length=steps)
    return state, tok, active, rem, toks, emitted


def _donate_argnums(*argnums):
    # buffer donation is a no-op (and warns) on CPU; donate the decode
    # state + carries everywhere it is actually honoured
    return () if jax.default_backend() == "cpu" else argnums


# Jitted entry points are cached on the Model's full constructor identity
# (config, prune, slots, remat knobs) — all hashable — NOT on Model
# instances: a Model-keyed cache would pin jit caches (and their
# params-sized constants) for every short-lived Model/ServeLoop ever
# created. Functionally identical Models share one compiled program.


def _model_key(model: Model):
    return (model.cfg, model.prune, model.decode_slots, model.remat,
            model.remat_policy)


def _rebuild(cfg, prune, slots, remat, remat_policy) -> Model:
    return Model(cfg, prune, remat=remat, decode_slots=slots,
                 remat_policy=remat_policy)


@functools.lru_cache(maxsize=32)
def _block_fn(key, steps: int):
    model = _rebuild(*key)
    return jax.jit(functools.partial(decode_block, model, steps=steps),
                   donate_argnums=_donate_argnums(1, 2))


@functools.lru_cache(maxsize=32)
def _masked_block_fn(key, steps: int, eos: int):
    model = _rebuild(*key)
    fn = functools.partial(decode_block_masked, model, steps=steps, eos=eos)
    return jax.jit(fn, donate_argnums=_donate_argnums(1, 2, 3, 4))


@functools.lru_cache(maxsize=32)
def _prefill_fn(key):
    return jax.jit(_rebuild(*key).prefill)


@functools.lru_cache(maxsize=32)
def _prefill_one_fn(key):
    return jax.jit(_rebuild(*key).prefill_one)


def _jit_decode_block(model: Model, steps: int):
    return _block_fn(_model_key(model), steps)


def _admit_lane_state(state, tok, lane, fresh, logits):
    """One-dispatch admission: splice `fresh` into `lane` and seed its
    first token from the prefill logits (state/tok donated in place)."""
    state = lane_insert(state, lane, fresh)
    tok = tok.at[lane].set(jnp.argmax(logits, -1).astype(tok.dtype))
    return state, tok


@functools.lru_cache(maxsize=1)
def _admit_fn():
    return jax.jit(_admit_lane_state, donate_argnums=_donate_argnums(0, 1))


def generate_scan(model: Model, params, batch, steps: int):
    """lax.scan'd decode loop (single dispatch; production serving path).

    The decode block is jitted with the (state, token) carry donated; under
    an outer jit the inner jit inlines and the whole call stays traceable.
    """
    logits, state = jax.jit(model.prefill)(params, batch)
    tok0 = jnp.argmax(logits, -1)
    state, _, toks = _jit_decode_block(model, steps)(params, state, tok0)
    return toks.swapaxes(0, 1), state


# ---------------------------------------------------------------------------
# Requests + per-request serving metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request. `arrival` is seconds from `run()` start
    (0 = already waiting); `submit()` keeps the queue arrival-ordered."""
    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0


@dataclasses.dataclass
class RequestStats:
    rid: int
    prompt_len: int
    max_new: int
    lane: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_arrival: float = 0.0     # run-relative seconds
    t_admit: float = 0.0       # prefilled + spliced into a lane
    t_first: float = 0.0       # first generated token on the host
    t_done: float = 0.0
    occupancy: float = 0.0     # mean cache fill fraction at completion

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def decode_tps(self) -> float:
        return len(self.tokens) / max(self.t_done - self.t_admit, 1e-9)


class ServeLoop:
    """Lane-granular continuous batching: fixed decode lanes + request queue.

    New-style use::

        loop = ServeLoop(model, params, lanes=4, eos=2, block=8)
        loop.submit(prompt_a, max_new=64)     # any prompt length ≤ max
        loop.submit(prompt_b, max_new=16)
        stats = loop.run()                    # List[RequestStats]

    Lanes are admitted independently (prefill_one + lane_insert), freed on
    EOS/budget **in-device**, and refilled from the queue mid-flight. The
    legacy all-lanes API (`admit(prompts)` + `step()`/`step_block()`) drives
    the same engine with a single full-batch prefill.

    `block` sets how many tokens each dispatch decodes: the scanned block
    amortizes launch overhead across `block` tokens, at the cost of up to
    `block - 1` speculative steps after a lane hits EOS/budget (their
    outputs are masked out in-device).

    Prompts are prefilled at their *exact* length, which keeps a
    lane-inserted prefill bit-identical to a fresh full-batch prefill but
    compiles one prefill program per distinct length (cached for the
    process lifetime). Callers with highly diverse traffic should bucket
    prompt lengths themselves before `submit()` if compile stalls matter.
    """

    def __init__(self, model: Model, params, lanes: int,
                 prompt_len: Optional[int] = None, max_new: int = 64,
                 eos: int = -1, block: int = 1):
        self.model = model
        self.params = params
        self.lanes = lanes
        self.max_new = max_new
        self.eos = eos
        self.prompt_len = prompt_len          # legacy hint; not enforced
        self.block = max(1, block)
        self._prefill = _prefill_fn(_model_key(model))
        self._prefill_one = _prefill_one_fn(_model_key(model))
        self.state = None
        self.tok = None
        self.active = np.zeros(lanes, bool)
        self.remaining = np.zeros(lanes, np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(lanes)]
        self.done: List[List[int]] = []
        self.queue: Deque[Request] = deque()
        self.stats: Dict[int, RequestStats] = {}
        self.completed: List[RequestStats] = []
        self._lane_rid: List[Optional[int]] = [None] * lanes
        self._next_rid = 0
        self._t0: Optional[float] = None

    # -- time ----------------------------------------------------------------

    def _now(self) -> float:
        return 0.0 if self._t0 is None else time.monotonic() - self._t0

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, max_new: Optional[int] = None,
               arrival: float = 0.0) -> int:
        """Queue one request; returns its rid. Prompt: [t] token ids."""
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(prompt)
        req = Request(rid, prompt,
                      self.max_new if max_new is None else max_new, arrival)
        if self.queue and arrival < self.queue[-1].arrival:
            # keep arrival order (FIFO among ties) — schedule() peeks head
            idx = next(i for i, r in enumerate(self.queue)
                       if r.arrival > arrival)
            self.queue.insert(idx, req)
        else:
            self.queue.append(req)
        self.stats[rid] = RequestStats(rid, len(prompt), req.max_new,
                                       t_arrival=arrival)
        return rid

    # -- admission -----------------------------------------------------------

    def _ensure_state(self):
        if self.state is None:
            self.state = self.model.init_decode_state(self.lanes)
            self.tok = jnp.zeros((self.lanes,), jnp.int32)

    def _admit_lane(self, lane: int, req: Request):
        """Prefill one request and splice it into `lane` of the live state."""
        self._ensure_state()
        logits, fresh = self._prefill_one(self.params,
                                          jnp.asarray(req.prompt))
        self.state, self.tok = _admit_fn()(self.state, self.tok, lane,
                                           fresh, logits)
        self.active[lane] = req.max_new > 0
        self.remaining[lane] = max(req.max_new, 0)
        self.outputs[lane] = []
        self._lane_rid[lane] = req.rid
        st = self.stats[req.rid]
        st.lane = lane
        st.t_admit = self._now()
        if req.max_new <= 0:                   # prefill-only request
            st.t_first = st.t_admit            # ttft == prefill completion
            self._finish_lane(lane, self._now())

    def schedule(self) -> int:
        """Admit queued, already-arrived requests into free lanes."""
        n = 0
        now = self._now()
        while self.queue and not self.active.all():
            if self.queue[0].arrival > now:
                break
            req = self.queue.popleft()
            lane = int(np.flatnonzero(~self.active)[0])
            self._admit_lane(lane, req)
            n += 1
        return n

    def admit(self, prompts: np.ndarray):
        """Legacy all-lanes admission: prompts [lanes, prompt_len] are
        prefilled in one batch (one compile, no lane splicing) and every
        lane restarts with the shared `max_new` budget."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        batch = {"tokens": jnp.asarray(prompts)}
        logits, self.state = self._prefill(self.params, batch)
        self.tok = jnp.argmax(logits, -1).astype(jnp.int32)
        self.active[:] = self.max_new > 0
        self.remaining[:] = max(self.max_new, 0)
        self.outputs = [[] for _ in range(self.lanes)]
        now = self._now()
        for lane in range(self.lanes):
            rid = self._next_rid
            self._next_rid += 1
            self._lane_rid[lane] = rid
            self.stats[rid] = RequestStats(
                rid, prompts.shape[1], self.max_new, lane=lane,
                t_arrival=now, t_admit=now)

    # -- decode --------------------------------------------------------------

    def step(self) -> bool:
        """One decode step over all lanes; returns True while any lane live."""
        return self.step_block(1)

    def step_block(self, steps: int = 0) -> bool:
        """Decode `steps` (default: self.block) tokens in one dispatch.

        Finished lanes stop writing in-device; the host side consumes the
        (token, emitted) pairs with vectorized numpy — no per-token loop.
        """
        steps = steps or self.block
        if self.state is None or not self.active.any():
            return bool(self.active.any())
        fn = _masked_block_fn(_model_key(self.model), steps, self.eos)
        was_active = self.active.copy()
        self.state, self.tok, active, rem, toks, emitted = fn(
            self.params, self.state, self.tok,
            jnp.asarray(self.active), jnp.asarray(self.remaining))
        host_toks = np.asarray(toks)                       # [steps, lanes]
        host_emit = np.asarray(emitted)                    # [steps, lanes]
        self.active = np.asarray(active).copy()
        self.remaining = np.asarray(rem).astype(np.int32)
        now = self._now()
        for lane in np.flatnonzero(host_emit.any(axis=0)):
            lane = int(lane)
            new = host_toks[host_emit[:, lane], lane].tolist()
            if not self.outputs[lane]:
                rid = self._lane_rid[lane]
                if rid is not None:
                    self.stats[rid].t_first = now
            self.outputs[lane].extend(new)
        for lane in np.flatnonzero(was_active & ~self.active):
            self._finish_lane(int(lane), now)
        return bool(self.active.any())

    def _finish_lane(self, lane: int, now: float):
        rid = self._lane_rid[lane]
        if rid is None:
            return
        st = self.stats[rid]
        st.tokens = list(self.outputs[lane])
        st.t_done = now
        st.occupancy = self._lane_occupancy(lane)
        self.completed.append(st)
        self.done.append(st.tokens)
        self._lane_rid[lane] = None

    def _lane_occupancy(self, lane: int) -> float:
        kv = self.state.kv if self.state is not None else None
        if kv is None:
            return 0.0
        fill = np.asarray(kv.fill)                         # [L, lanes]
        return float(fill[:, lane].mean() / kv.slots)

    # -- driver ---------------------------------------------------------------

    def run(self) -> List[RequestStats]:
        """Drive until the queue is drained and every lane is idle."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        while self.queue or self.active.any():
            self.schedule()
            if not self.active.any():
                if not self.queue:     # e.g. a trailing prefill-only request
                    continue
                wait = self.queue[0].arrival - self._now()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
                continue
            self.step_block()
        return self.completed

    def aggregate(self) -> Dict[str, float]:
        """Serving metrics over completed requests."""
        if not self.completed:
            return {"requests": 0.0, "tokens": 0.0, "wall_s": 0.0,
                    "tokens_per_s": 0.0, "mean_latency_s": 0.0,
                    "mean_occupancy": 0.0}
        toks = sum(len(s.tokens) for s in self.completed)
        t_end = max(s.t_done for s in self.completed)
        t_begin = min(s.t_arrival for s in self.completed)
        wall = max(t_end - t_begin, 1e-9)
        return {
            "requests": float(len(self.completed)),
            "tokens": float(toks),
            "wall_s": wall,
            "tokens_per_s": toks / wall,
            "mean_latency_s": float(np.mean([s.latency
                                             for s in self.completed])),
            "mean_occupancy": float(np.mean([s.occupancy
                                             for s in self.completed])),
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="unicaim",
                    choices=["unicaim", "h2o", "streaming", "dense"])
    ap.add_argument("--fused", action="store_true",
                    help="single-pass fused decode engine (unicaim only)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-token Python loop instead of lax.scan")
    ap.add_argument("--serve", action="store_true",
                    help="continuous-batching demo: 2x batch staggered "
                         "variable-length requests through ServeLoop")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    budget = max(64, args.prompt_len // 2)
    if args.policy == "unicaim":
        prune = baselines.unicaim(heavy=budget, reserve=64,
                                  select_k=max(16, budget // 8),
                                  fused=args.fused)
    elif args.policy == "h2o":
        prune = baselines.h2o(heavy=budget, reserve=64)
    elif args.policy == "streaming":
        prune = baselines.streaming(budget + 64)
    else:
        prune = baselines.dense(args.prompt_len + args.new_tokens)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if args.serve:
        loop = ServeLoop(model, params, lanes=args.batch,
                         max_new=args.new_tokens, block=8)
        lens = (args.prompt_len, max(8, args.prompt_len // 2))
        for i in range(2 * args.batch):
            loop.submit(rng.integers(0, cfg.vocab_size, lens[i % len(lens)]),
                        max_new=args.new_tokens // (1 + i % 2))
        t0 = time.time()
        stats = loop.run()
        dt = time.time() - t0
        agg = loop.aggregate()
        for s in stats:
            print(f"  req {s.rid}: lane={s.lane} prompt={s.prompt_len} "
                  f"new={len(s.tokens)} latency={s.latency:.2f}s "
                  f"occ={s.occupancy:.2f}")
        print(f"arch={cfg.name} policy={args.policy} fused={args.fused} "
              f"served {len(stats)} reqs on {args.batch} lanes in {dt:.2f}s "
              f"({agg['tokens_per_s']:.1f} tok/s)")
        return

    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts)}
    t0 = time.time()
    if args.no_scan:
        toks, _ = greedy_generate(model, params, batch, args.new_tokens)
    else:
        toks, _ = generate_scan(model, params, batch, args.new_tokens)
    toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    mode = "loop" if args.no_scan else "scan"
    print(f"arch={cfg.name} policy={args.policy} mode={mode} "
          f"fused={args.fused} cache_slots={prune.slots} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
