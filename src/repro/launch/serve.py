"""Serving driver: batched prefill + decode with the UniCAIM cache.

Implements a slot-based continuous-batching loop: a fixed number of decode
lanes; finished sequences free their lane for the next queued request. The
per-step work is a single jitted multi-step `lax.scan` over the whole lane
batch — one dispatch per block of tokens instead of one per token — with
the decode state (KV cache buffers) donated so XLA updates them in place.
This is the paper's target regime (memory-bound autoregressive decoding),
where per-token Python dispatch otherwise dominates the step time.
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model


def greedy_generate(model: Model, params, batch, steps: int,
                    temperature: float = 0.0, key=None):
    """Prefill + `steps` decode steps. Returns [B, steps] generated ids.

    One Python dispatch per token — the reference loop (and the only one
    that supports sampling); production serving uses the scanned paths.
    """
    logits, state = jax.jit(model.prefill)(params, batch)
    decode = jax.jit(model.decode_step)
    toks = []
    tok = jnp.argmax(logits, -1)
    for i in range(steps):
        toks.append(tok)
        logits, state = decode(params, state, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, -1)
    return jnp.stack(toks, axis=1), state


def decode_block(model: Model, params, state, tok, steps: int):
    """`steps` greedy decode steps as one lax.scan (pure, traceable).

    tok: [B] current token → (state, next_tok [B], toks [steps, B]) where
    toks[0] == tok (the scan emits, then advances — same order as the
    per-token loop).
    """
    def body(carry, _):
        state, tok = carry
        logits, state = model.decode_step(params, state, tok)
        nxt = jnp.argmax(logits, -1)
        return (state, nxt), tok

    (state, tok), toks = jax.lax.scan(body, (state, tok), None, length=steps)
    return state, tok, toks


def _donate_argnums():
    # buffer donation is a no-op (and warns) on CPU; donate the decode
    # state + token carry everywhere it is actually honoured
    return () if jax.default_backend() == "cpu" else (1, 2)


@functools.lru_cache(maxsize=64)
def _jit_decode_block(model: Model, steps: int):
    return jax.jit(functools.partial(decode_block, model, steps=steps),
                   donate_argnums=_donate_argnums())


def generate_scan(model: Model, params, batch, steps: int):
    """lax.scan'd decode loop (single dispatch; production serving path).

    The decode block is jitted with the (state, token) carry donated; under
    an outer jit the inner jit inlines and the whole call stays traceable.
    """
    logits, state = jax.jit(model.prefill)(params, batch)
    tok0 = jnp.argmax(logits, -1)
    state, _, toks = _jit_decode_block(model, steps)(params, state, tok0)
    return toks.swapaxes(0, 1), state


class ServeLoop:
    """Minimal continuous batching: fixed decode lanes + request queue.

    `block` sets how many tokens each dispatch decodes: the scanned block
    amortizes launch overhead across `block` tokens, at the cost of up to
    `block - 1` speculative steps after a lane hits EOS/budget (their
    outputs are dropped by the host-side bookkeeping below).
    """

    def __init__(self, model: Model, params, lanes: int, prompt_len: int,
                 max_new: int = 64, eos: int = -1, block: int = 1):
        self.model = model
        self.params = params
        self.lanes = lanes
        self.max_new = max_new
        self.eos = eos
        self.prompt_len = prompt_len
        self.block = max(1, block)
        self._prefill = jax.jit(model.prefill)
        self.state = None
        self.remaining = np.zeros(lanes, np.int64)
        self.outputs: List[List[int]] = [[] for _ in range(lanes)]
        self.done: List[List[int]] = []
        self.tok = None

    def admit(self, prompts: np.ndarray):
        """prompts: [lanes, prompt_len] — (re)fill all lanes at once."""
        batch = {"tokens": jnp.asarray(prompts)}
        logits, self.state = self._prefill(self.params, batch)
        self.tok = jnp.argmax(logits, -1)
        self.remaining[:] = self.max_new
        self.outputs = [[] for _ in range(self.lanes)]

    def step(self) -> bool:
        """One decode step over all lanes; returns True while any lane live."""
        return self.step_block(1)

    def step_block(self, steps: int = 0) -> bool:
        """Decode `steps` (default: self.block) tokens in one dispatch."""
        steps = steps or self.block
        if self.state is None or not (self.remaining > 0).any():
            return False
        fn = _jit_decode_block(self.model, steps)
        self.state, self.tok, toks = fn(self.params, self.state, self.tok)
        host = np.asarray(toks)                             # [steps, lanes]
        for t in range(host.shape[0]):
            for i in range(self.lanes):
                if self.remaining[i] > 0:
                    self.outputs[i].append(int(host[t, i]))
                    self.remaining[i] -= 1
                    if host[t, i] == self.eos:
                        self.remaining[i] = 0
        return bool((self.remaining > 0).any())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="unicaim",
                    choices=["unicaim", "h2o", "streaming", "dense"])
    ap.add_argument("--fused", action="store_true",
                    help="single-pass fused decode engine (unicaim only)")
    ap.add_argument("--no-scan", action="store_true",
                    help="per-token Python loop instead of lax.scan")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    budget = max(64, args.prompt_len // 2)
    if args.policy == "unicaim":
        prune = baselines.unicaim(heavy=budget, reserve=64,
                                  select_k=max(16, budget // 8),
                                  fused=args.fused)
    elif args.policy == "h2o":
        prune = baselines.h2o(heavy=budget, reserve=64)
    elif args.policy == "streaming":
        prune = baselines.streaming(budget + 64)
    else:
        prune = baselines.dense(args.prompt_len + args.new_tokens)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len))
    batch = {"tokens": jnp.asarray(prompts)}
    t0 = time.time()
    if args.no_scan:
        toks, _ = greedy_generate(model, params, batch, args.new_tokens)
    else:
        toks, _ = generate_scan(model, params, batch, args.new_tokens)
    toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    mode = "loop" if args.no_scan else "scan"
    print(f"arch={cfg.name} policy={args.policy} mode={mode} "
          f"fused={args.fused} cache_slots={prune.slots} "
          f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
