import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
#
# Multi-pod dry-run (EXPERIMENTS.md §Dry-run): for every assigned
# (architecture × input shape) cell, lower + compile the production step
# function on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh,
# then record memory_analysis / cost_analysis / collective bytes for the
# roofline (launch/roofline.py). ShapeDtypeStruct inputs — no allocation.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (PruneConfig, ShapeConfig, SHAPES,  # noqa: E402
                                SHAPES_BY_NAME, get_config, list_archs)
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.train import make_train_step, TrainState  # noqa: E402
from repro.models.transformer import Model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime.flags import unroll_scans  # noqa: E402
from repro.runtime.sharding import (decode_state_pspecs, named_sharding,  # noqa: E402
                                    params_pspecs, use_mesh)

ARCHS = [
    "whisper-base", "minitron-8b", "starcoder2-3b", "phi3-medium-14b",
    "granite-3-2b", "deepseek-v3-671b", "grok-1-314b", "zamba2-7b",
    "mamba2-1.3b", "llava-next-mistral-7b",
]

# archs whose bf16 params exceed ~8 GB/chip under TP-16 alone → keep ZeRO
# (fsdp) sharding even for inference cells (per-layer all-gather).
_BIG = {"deepseek-v3-671b", "grok-1-314b"}


def cell_notes(arch: str, shape: ShapeConfig) -> str:
    notes = []
    cfg = get_config(arch)
    if cfg.family == "ssm":
        notes.append("UniCAIM inapplicable (no KV cache); native O(1)-state "
                     "decode — see DESIGN.md §Arch-applicability")
    if cfg.family == "hybrid":
        notes.append("UniCAIM applies to the shared-attention caches only")
    if shape.name == "long_500k" and cfg.has_attention:
        notes.append("500k decode runs WITH UniCAIM dynamic pruning (dense "
                     "full-attention variant skipped as intractable — the "
                     "technique is what makes this cell feasible)")
    if arch == "whisper-base" and shape.kind != "train":
        notes.append("decoder stress config (real model ctx=448); "
                     "conv frontend stubbed to frame embeddings")
    return "; ".join(notes)


def make_prune(shape: ShapeConfig, policy: str = "unicaim",
               opts=()) -> PruneConfig:
    blocks = 1
    kv_dtype = "bf16"
    for o in opts:
        if o.startswith("blocks"):
            blocks = int(o[6:])
        if o == "kvint8":
            kv_dtype = "int8"
    if shape.kind == "decode":
        slots = shape.seq_len
        return PruneConfig(
            policy=policy, heavy_budget=slots - 64, reserve=64,
            sink_tokens=4, recent_window=64,
            select_k=min(2048, slots // 16), score_bits=3, query_bits=4,
            select_blocks=blocks, kv_dtype=kv_dtype)
    if shape.kind == "prefill":
        heavy = max(shape.seq_len // 8, 512)
        return PruneConfig(policy=policy, heavy_budget=heavy, reserve=64,
                           sink_tokens=4, recent_window=64,
                           select_k=min(1024, heavy // 4))
    return PruneConfig(policy=policy)        # train: cache-free


def cost_basis(cfg):
    """(make(counts)→cfg, full_counts): layer-count knobs whose HLO cost is
    affine — the dry-run probes shallow unrolled variants and extrapolates,
    because XLA cost_analysis counts a while-loop body once regardless of
    trip count (see runtime/flags.py)."""
    if cfg.family == "mla_moe":
        full = {"dense": cfg.moe.dense_first_k,
                "moe": cfg.num_layers - cfg.moe.dense_first_k}

        def make(c):
            return dataclasses.replace(
                cfg, num_layers=c["dense"] + c["moe"],
                moe=dataclasses.replace(cfg.moe, dense_first_k=c["dense"]))
    elif cfg.family == "encdec":
        full = {"enc": cfg.enc_layers, "dec": cfg.dec_layers}

        def make(c):
            return dataclasses.replace(cfg, enc_layers=c["enc"],
                                       dec_layers=c["dec"])
    elif cfg.family == "hybrid":
        period = cfg.attn_period
        full = {"group": cfg.num_layers // period,
                "tail": cfg.num_layers % period}

        def make(c):
            return dataclasses.replace(
                cfg, num_layers=c["group"] * period + c["tail"])
        if full["tail"] == 0:
            full.pop("tail")
    else:
        full = {"layers": cfg.num_layers}

        def make(c):
            return dataclasses.replace(cfg, num_layers=c["layers"])
    return make, full


def build_cell(cfg, shape: ShapeConfig, policy: str = "unicaim",
               remat: bool = True, opts=()):
    """Returns (fn, arg_shapes tuple, arg_shardings tuple, donate).
    opts: optimization variants — 'blocksN' (shard-local selection),
    'rematdots', 'losschunkN' (chunked CE)."""
    prune = make_prune(shape, policy, opts)
    remat_policy = "dots" if "rematdots" in opts else "nothing"
    loss_chunk = 0
    for o in opts:
        if o.startswith("losschunk"):
            loss_chunk = int(o[9:])
        if o.startswith("chunk") and not o.startswith("chunkmirror"):
            cfg = dataclasses.replace(cfg, attn_chunk=int(o[5:]))
        if o == "moeep":
            cfg = dataclasses.replace(cfg, moe_ep=True)
    b = shape.global_batch
    key = jax.random.PRNGKey(0)

    def batch_shapes(t):
        bs = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        if cfg.family == "encdec":
            bs["enc_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend != "none":
            bs[f"{cfg.frontend}_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
        return bs

    def batch_shardings(bs):
        return {k: named_sharding(("batch",) + (None,) * (v.ndim - 1),
                                  v.shape) for k, v in bs.items()}

    if shape.kind == "train":
        model = Model(cfg, prune, remat=remat, remat_policy=remat_policy)
        opt_cfg = adamw.AdamWConfig(
            quantized_state=cfg.param_count() > 2e10)
        p_shapes = jax.eval_shape(model.init, key)
        opt_shapes = jax.eval_shape(
            lambda p: adamw.init(p, opt_cfg), p_shapes)
        st_shapes = TrainState(params=p_shapes, opt=opt_shapes,
                               step=jax.ShapeDtypeStruct((), jnp.int32))
        bs = batch_shapes(shape.seq_len)
        st_specs = params_pspecs(st_shapes)
        st_sh = jax.tree.map(lambda s: NamedSharding(_MESH[0], s), st_specs,
                             is_leaf=lambda x: isinstance(x, P))
        fn = make_train_step(model, opt_cfg, total_steps=10000,
                             loss_chunk=loss_chunk)
        return fn, (st_shapes, bs), (st_sh, batch_shardings(bs)), (0,)

    if shape.kind == "prefill":
        model = Model(cfg, prune, remat=False)
        p_shapes = jax.eval_shape(model.init, key)
        p_specs = params_pspecs(p_shapes)
        p_sh = jax.tree.map(lambda s: NamedSharding(_MESH[0], s), p_specs,
                            is_leaf=lambda x: isinstance(x, P))
        bs = batch_shapes(shape.seq_len)
        fn = model.prefill
        return fn, (p_shapes, bs), (p_sh, batch_shardings(bs)), ()

    # decode: one new token against a cache of seq_len slots
    model = Model(cfg, prune, remat=False, decode_slots=shape.seq_len)
    p_shapes = jax.eval_shape(model.init, key)
    p_specs = params_pspecs(p_shapes)
    p_sh = jax.tree.map(lambda s: NamedSharding(_MESH[0], s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))
    cross = cfg.frontend_len if cfg.family == "encdec" else 0
    st_shapes = jax.eval_shape(
        lambda: model.init_decode_state(b, cross_len=cross))
    st_specs = decode_state_pspecs(st_shapes)
    st_sh = jax.tree.map(lambda s: NamedSharding(_MESH[0], s), st_specs,
                         is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_sh = named_sharding(("batch",), tok.shape)
    fn = model.decode_step
    return fn, (p_shapes, st_shapes, tok), (p_sh, st_sh, tok_sh), (1,)


_MESH = [None]   # active mesh holder for build_cell's sharding closures


def _compile_cell(cfg, shape, policy, remat, opts=()):
    fn, arg_shapes, arg_sh, donate = build_cell(cfg, shape, policy, remat,
                                                opts)
    jitted = jax.jit(fn, in_shardings=arg_sh, donate_argnums=donate)
    return jitted.lower(*arg_shapes).compile()


def _metrics(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = roofline.parse_collective_bytes(compiled.as_text())
    m = {"flops": float(cost.get("flops", 0.0)),
         "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        m[f"coll_{k}"] = float(v)
    return m


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: str = "unicaim", remat: bool = True,
             probes: bool = True, opts=()) -> dict:
    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    _MESH[0] = mesh
    overrides = {}
    if shape.kind != "train" and arch not in _BIG:
        overrides["fsdp"] = ()          # inference: TP-only params
    t0 = time.time()
    with use_mesh(mesh, **overrides):
        # 1) full-depth scanned compile: the multi-pod PROOF + memory budget
        compiled = _compile_cell(cfg, shape, policy, remat, opts)
        t_compile = time.time() - t0

        # 2) shallow UNROLLED probes → exact per-layer cost extrapolation.
        # Base point is 2 layers/segment: 1-layer programs take different
        # fusion paths and break affinity (observed on whisper-base);
        # 2 ↔ 3 is cleanly affine.
        make, full = cost_basis(cfg)
        base = {k: 2 for k in full}
        probe_cost = {}
        if probes:
            with unroll_scans(True):
                probe_cost["base"] = _metrics(
                    _compile_cell(make(base), shape, policy, remat, opts))
                for dim in full:
                    pt = dict(base)
                    pt[dim] = 3
                    probe_cost[dim] = _metrics(
                        _compile_cell(make(pt), shape, policy, remat, opts))
        t_probe = time.time() - t0 - t_compile

    mem = compiled.memory_analysis()
    if probes:
        keys = probe_cost["base"].keys()
        per_dim = {dim: {k: probe_cost[dim][k] - probe_cost["base"][k]
                         for k in keys} for dim in full}
        totals = {}
        for k in keys:
            c0 = probe_cost["base"][k] - sum(base[d] * per_dim[d][k]
                                             for d in full)
            totals[k] = max(0.0, c0 + sum(full[d] * per_dim[d][k]
                                          for d in full))
    else:
        totals = _metrics(compiled)

    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape_name, "policy": policy,
        "opts": list(opts),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "flops": totals["flops"],
        "bytes_accessed": totals["bytes_accessed"],
        "collective_bytes": totals["coll_total"],
        "collectives": {k[5:]: v for k, v in totals.items()
                        if k.startswith("coll_")},
        "arg_bytes_per_dev": int(mem.argument_size_in_bytes),
        "temp_bytes_per_dev": int(mem.temp_size_in_bytes),
        "peak_bytes_per_dev": int(mem.peak_memory_in_bytes),
        "output_bytes_per_dev": int(mem.output_size_in_bytes),
        "model_flops": roofline.model_flops(cfg, shape),
        "param_count": int(cfg.param_count()),
        "active_param_count": int(cfg.active_param_count()),
        "compile_s": round(t_compile, 2), "probe_s": round(t_probe, 2),
        "notes": cell_notes(arch, shape),
    }
    rec.update({k: v for k, v in roofline.summarize(rec).items()
                if k not in rec})
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="unicaim")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: blocksN,rematdots,losschunkN")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.policy != "unicaim":
                    tag += f"_{args.policy}"
                opts = tuple(o for o in args.opt.split(",") if o)
                for o in opts:
                    tag += f"_{o}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, args.policy,
                                   remat=not args.no_remat, opts=opts)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[ ok ] {tag}: flops/dev={rec['flops']:.3e} "
                          f"bytes/dev={rec['bytes_accessed']:.3e} "
                          f"coll/dev={rec['collective_bytes']:.3e} "
                          f"peak={rec['peak_bytes_per_dev']/2**30:.2f}GiB "
                          f"dom={rec['dominant']} "
                          f"compile={rec['compile_s']:.1f}s", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    print(f"\n{len(failures)} failures")
    for tag, err in failures:
        print(" ", tag, err[:200])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
