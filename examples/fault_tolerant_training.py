"""End-to-end training driver with fault tolerance — trains a ~small LM for
a few hundred steps through the production loop: synthetic data pipeline,
AdamW (+warmup-cosine), periodic checkpoints, an INJECTED node failure at
step 120 (the loop restores from the last checkpoint and continues), and a
final eval rollout. This is deliverable (b)'s end-to-end driver.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py [--steps 300]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.data.pipeline import SyntheticSource
from repro.launch.serve import greedy_generate
from repro.launch.train import init_train_state, make_train_step
from repro.models.transformer import Model
from repro.optim import adamw
from repro.runtime import fault

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced(get_config("granite-3-2b"), num_layers=3, d_model=128,
                  n_heads=8, n_kv_heads=4, head_dim=16, d_ff=256,
                  vocab_size=512)
    prune = baselines.unicaim(heavy=80, reserve=16, select_k=32,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"training {cfg.name}-reduced: {n_params/1e6:.2f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, args.steps,
                                      peak_lr=3e-3, warmup=20))
    src = SyntheticSource(cfg.vocab_size, args.seq, seed=0)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)

    crash = {"armed": True}
    def inject(step):
        if step == 120 and crash["armed"]:
            crash["armed"] = False
            print(">>> injecting node failure at step 120 <<<")
            raise RuntimeError("simulated preemption")

    def data_iter(step):
        return {"tokens": jnp.asarray(src.batch(step, args.batch))}

    def on_metrics(step, m):
        if step % 25 == 0:
            print(f"  step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}")

    state, stats = fault.run_training(
        step_fn, state, data_iter, args.steps, ckpt,
        fault.FaultConfig(ckpt_every=50, max_restarts=2,
                          step_deadline_s=30.0),
        inject_failure=inject, on_metrics=on_metrics)

    print(f"finished: {stats.steps} productive steps, "
          f"{stats.restarts} restart(s), "
          f"loss {stats.losses[0]:.3f} → {stats.losses[-1]:.3f}")

    toks, _ = greedy_generate(model, state.params,
                              {"tokens": jnp.asarray(src.batch(9999, 2)[:, :64])},
                              steps=16)
    print("sample generation ids:", np.asarray(toks)[0][:16].tolist())
    shutil.rmtree(ckpt_dir, ignore_errors=True)

if __name__ == "__main__":
    main()
