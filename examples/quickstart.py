"""Quickstart: the UniCAIM technique on a small model in ~2 minutes (CPU).

  1. build a reduced granite-3-2b with UniCAIM static-dynamic pruning
  2. prefill a prompt → one-shot static pruning fills the fixed-slot cache
  3. decode with CAM-mode approximate scoring + top-k + static eviction
  4. compare against the dense-cache reference

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model

def main():
    cfg = reduced(get_config("granite-3-2b"))
    print(f"model: {cfg.name} (reduced) — {cfg.num_layers}L d{cfg.d_model} "
          f"{cfg.n_heads}H/{cfg.n_kv_heads}KV")

    # the paper's technique: H heavy slots + M reserve, 3-bit CAM mirror,
    # top-k dynamic selection, accumulated-score static eviction
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              score_bits=3, query_bits=4,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    params = model.init(jax.random.PRNGKey(0))

    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 80), 0,
                                cfg.vocab_size)
    logits, state = jax.jit(model.prefill)(params, {"tokens": prompt})
    kept = int(state.kv.valid[0, 0, 0].sum())
    print(f"prefill: 80 prompt tokens → {kept} kept "
          f"(budget {prune.slots} slots, {prune.score_bits}-bit mirror)")

    dense = Model(cfg, baselines.dense(256))
    lg_d, st_d = jax.jit(dense.prefill)(params, {"tokens": prompt})

    decode = jax.jit(model.decode_step)
    decode_d = jax.jit(dense.decode_step)
    tok = jnp.argmax(logits, -1)
    drift = 0.0
    for i in range(24):
        logits, state = decode(params, state, tok)
        lg_d, st_d = decode_d(params, st_d, tok)
        drift += float(jnp.mean(jnp.abs(jax.nn.softmax(logits)
                                        - jax.nn.softmax(lg_d))))
        tok = jnp.argmax(lg_d, -1)
    print(f"decode: 24 steps, mean softmax drift vs dense cache: "
          f"{drift / 24:.2e} (untrained weights — see "
          f"benchmarks/bench_accuracy.py for the trained-model comparison)")
    print(f"cache is fixed-size: {int(state.kv.valid[0,0,0].sum())} slots "
          f"after decoding past the budget (dense grew to "
          f"{int(st_d.kv.valid[0,0,0].sum())})")

if __name__ == "__main__":
    main()
