"""Long-context serving with a fixed KV budget — the paper's target workload.

Drives the lane-granular continuous-batching ServeLoop with staggered,
variable-length requests: each request carries its own prompt and budget,
is prefilled on its own and spliced into a free lane mid-flight, and lanes
are recycled the moment a request hits its budget — the fixed-slot UniCAIM
cache stays busy under mixed traffic. Compares policies side by side on
the same request set and reports per-request latency, tokens/s, and cache
occupancy.

Run:  PYTHONPATH=src python examples/long_context_serving.py
"""
import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import ServeLoop
from repro.models.transformer import Model

LANES = 2
REQUESTS = [      # (prompt_len, max_new, arrival_s) — staggered, mixed sizes
    (192, 48, 0.0),
    (96, 16, 0.0),
    (160, 64, 0.1),
    (64, 24, 0.2),
    (192, 16, 0.4),
    (128, 32, 0.4),
]


def main():
    cfg = reduced(get_config("longchat-7b"))   # the paper's own eval model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, t) for t, _, _ in REQUESTS]
    params = None
    for policy, prune in (
        ("unicaim", baselines.unicaim(heavy=56, reserve=16, select_k=24,
                                      score_bits=3, sink_tokens=2,
                                      recent_window=8)),
        ("h2o", baselines.h2o(heavy=56, reserve=16)),
        ("streaming", baselines.streaming(72, sinks=2)),
        ("dense", baselines.dense(max(t + n for t, n, _ in REQUESTS) + 8)),
    ):
        model = Model(cfg, prune)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        # bucketed prefill (default) bounds the prefill jit cache;
        # chunk_prefill=64 interleaves long prefills with decode blocks;
        # grouped admission (default) batches same-bucket arrivals into
        # one prefill dispatch + one multi-lane splice
        loop = ServeLoop(model, params, lanes=LANES, block=8,
                         chunk_prefill=64)
        for prompt, (_, max_new, arrival) in zip(prompts, REQUESTS):
            loop.submit(prompt, max_new=max_new, arrival=arrival)
        stats = loop.run()
        agg = loop.aggregate()
        kv_bytes = sum(x.nbytes for x in jax.tree.leaves(loop.state.kv)) \
            if loop.state.kv is not None else 0
        print(f"{policy:10s} cache={prune.slots:4d} slots "
              f"kv={kv_bytes / 2**20:6.1f}MiB "
              f"{agg['tokens_per_s']:7.1f} tok/s  "
              f"mean_latency={agg['mean_latency_s']:.2f}s "
              f"p99_ttft={agg['p99_ttft_s']:.2f}s "
              f"occ={agg['mean_occupancy']:.2f} "
              f"prefill_programs={loop.prefill_programs()['loop_shapes']} "
              f"dispatches={loop.counters['prefill_dispatches']}pf/"
              f"{loop.counters['admit_dispatches']}adm "
              f"({loop.counters['grouped_requests']} grouped)")
        for s in sorted(stats, key=lambda s: s.rid):
            print(f"    req {s.rid}: lane={s.lane} prompt={s.prompt_len:4d} "
                  f"bucket={s.bucket:4d} chunks={s.prefill_chunks} "
                  f"new={len(s.tokens):3d} latency={s.latency:5.2f}s "
                  f"ttft={s.ttft:5.2f}s occ={s.occupancy:.2f} "
                  f"group={s.group_size}")


if __name__ == "__main__":
    main()
