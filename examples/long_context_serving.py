"""Long-context serving with a fixed KV budget — the paper's target workload.

Drives the lane-granular continuous-batching ServeLoop with staggered,
variable-length requests: each request carries its own prompt and budget,
is prefilled on its own and spliced into a free lane mid-flight, and lanes
are recycled the moment a request hits its budget — the fixed-slot UniCAIM
cache stays busy under mixed traffic. Compares policies side by side on
the same request set and reports per-request latency, tokens/s, and cache
occupancy.

The second section is the prefix-caching demo: every request shares one
64-token system prompt, and with `prefix_cache_bytes` set the ServeLoop's
radix trie lets each admission after the first resume from the cached
prefix rows — only the unique suffix is prefilled, bit-identical to
prefilling the whole prompt, and TTFT drops accordingly.

The third section multiplexes scenario-diverse traffic on one engine:
greedy bulk lanes, a sampled chat request with its own
`SamplingParams`, and a priority-5 latency-sensitive arrival that
preempts a busy bulk lane mid-decode (the victim resumes
token-identically) — all sharing ONE compiled decode-block program.

Run:  PYTHONPATH=src python examples/long_context_serving.py
"""
import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import Request, SamplingParams, ServeLoop
from repro.models.transformer import Model

LANES = 2
REQUESTS = [      # (prompt_len, max_new, arrival_s) — staggered, mixed sizes
    (192, 48, 0.0),
    (96, 16, 0.0),
    (160, 64, 0.1),
    (64, 24, 0.2),
    (192, 16, 0.4),
    (128, 32, 0.4),
]


def policy_comparison(cfg, rng):
    prompts = [rng.integers(0, cfg.vocab_size, t) for t, _, _ in REQUESTS]
    params = None
    for policy, prune in (
        ("unicaim", baselines.unicaim(heavy=56, reserve=16, select_k=24,
                                      score_bits=3, sink_tokens=2,
                                      recent_window=8)),
        ("h2o", baselines.h2o(heavy=56, reserve=16)),
        ("streaming", baselines.streaming(72, sinks=2)),
        ("dense", baselines.dense(max(t + n for t, n, _ in REQUESTS) + 8)),
    ):
        model = Model(cfg, prune)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        # bucketed prefill (default) bounds the prefill jit cache;
        # chunk_prefill=64 interleaves long prefills with decode blocks;
        # grouped admission (default) batches same-bucket arrivals into
        # one prefill dispatch + one multi-lane splice
        loop = ServeLoop(model, params, lanes=LANES, block=8,
                         chunk_prefill=64)
        for prompt, (_, max_new, arrival) in zip(prompts, REQUESTS):
            loop.submit(Request(prompt=prompt, max_new=max_new,
                                arrival=arrival))
        stats = loop.run()
        agg = loop.aggregate()
        kv_bytes = sum(x.nbytes for x in jax.tree.leaves(loop.state.kv)) \
            if loop.state.kv is not None else 0
        print(f"{policy:10s} cache={prune.slots:4d} slots "
              f"kv={kv_bytes / 2**20:6.1f}MiB "
              f"{agg['tokens_per_s']:7.1f} tok/s  "
              f"mean_latency={agg['mean_latency_s']:.2f}s "
              f"p99_ttft={agg['p99_ttft_s']:.2f}s "
              f"occ={agg['mean_occupancy']:.2f} "
              f"prefill_programs={loop.prefill_programs()['loop_shapes']} "
              f"dispatches={loop.counters['prefill_dispatches']}pf/"
              f"{loop.counters['admit_dispatches']}adm "
              f"({loop.counters['grouped_requests']} grouped)")
        for s in sorted(stats, key=lambda s: s.rid):
            print(f"    req {s.rid}: lane={s.lane} prompt={s.prompt_len:4d} "
                  f"bucket={s.bucket:4d} chunks={s.prefill_chunks} "
                  f"new={len(s.tokens):3d} latency={s.latency:5.2f}s "
                  f"ttft={s.ttft:5.2f}s occ={s.occupancy:.2f} "
                  f"group={s.group_size}")
    return params


def shared_system_prompt(cfg, params, rng):
    """Prefix caching on shared-system-prompt traffic: 8 requests, one
    64-token system prompt + 32-token unique questions. With the cache,
    admissions after the first skip straight to the suffix chunks."""
    prune = baselines.unicaim(heavy=112, reserve=16, select_k=24,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    system = rng.integers(0, cfg.vocab_size, 64)
    prompts = [np.concatenate([system, rng.integers(0, cfg.vocab_size, 32)])
               for _ in range(8)]
    print("\nshared system prompt (64 shared + 32 unique tokens):")
    for label, pcb in (("no reuse", 0), ("prefix cache", 64 << 20)):
        loop = ServeLoop(model, params, lanes=LANES, block=8,
                         chunk_prefill=32, prefix_cache_bytes=pcb)
        handles = [loop.submit(Request(prompt=p, max_new=16))
                   for p in prompts]
        loop.run()
        agg = loop.aggregate()
        extra = ""
        if pcb:
            extra = (f" hit_rate={agg['prefix_hit_rate']:.2f}"
                     f" dedup={agg['prefix_dedup_ratio']:.2f}"
                     f" reused={loop.counters['prefix_tokens_reused']}tok")
        print(f"  {label:12s} p50_ttft={agg['p50_ttft_s']:.3f}s "
              f"chunk_dispatches={loop.counters['chunk_dispatches']}"
              + extra)
        assert all(h.done for h in handles)


def mixed_priority_traffic(cfg, params, rng):
    """Chat + batch-offline + latency-sensitive classes on one engine:
    per-request knobs ride [lanes]-shaped runtime arrays (one compiled
    block program for the whole mix) and the priority-5 arrival preempts
    a bulk lane instead of queueing behind its 48-token budget."""
    prune = baselines.unicaim(heavy=56, reserve=16, select_k=24,
                              sink_tokens=2, recent_window=8)
    model = Model(cfg, prune)
    loop = ServeLoop(model, params, lanes=LANES, block=8, reserve_blocks=2)
    bulk = [loop.submit(Request(prompt=rng.integers(0, cfg.vocab_size, 96),
                                max_new=48, priority=0))
            for _ in range(LANES + 1)]
    chat = loop.submit(Request(
        prompt=rng.integers(0, cfg.vocab_size, 64), max_new=24, priority=1,
        sampling=SamplingParams(temperature=0.8, top_k=40), sample_seed=7))
    loop.schedule()                    # bulk saturates the lanes...
    loop._step_block()                 # ...and decodes one block
    urgent = loop.submit(Request(prompt=rng.integers(0, cfg.vocab_size, 32),
                                 max_new=8, priority=5))
    stats = {s.rid: s for s in loop.run()}
    print("\nmixed-priority traffic (bulk=0 / chat=1 / urgent=5):")
    for label, h in (*((f"bulk{i}", b) for i, b in enumerate(bulk)),
                     ("chat", chat), ("urgent", urgent)):
        s = stats[h.rid]
        print(f"  {label:7s} prio={s.priority} new={len(s.tokens):2d} "
              f"ttft={s.ttft:5.2f}s preemptions={s.preemptions}")
    print(f"  counters: preemptions={loop.counters['preemptions']} "
          f"reservations={loop.counters['reservations']} "
          f"block_programs={loop.counters['decode_block_programs']}")


def main():
    cfg = reduced(get_config("longchat-7b"))   # the paper's own eval model
    rng = np.random.default_rng(0)
    params = policy_comparison(cfg, rng)
    shared_system_prompt(cfg, params, rng)
    mixed_priority_traffic(cfg, params, rng)


if __name__ == "__main__":
    main()
