"""Long-context serving with a fixed KV budget — the paper's target workload.

Serves batched requests through the ServeLoop (continuous batching) with
UniCAIM pruning, decoding far past the cache budget with constant memory,
and reports tokens/s + cache occupancy. Compares policies side by side.

Run:  PYTHONPATH=src python examples/long_context_serving.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.launch.serve import ServeLoop
from repro.models.transformer import Model

PROMPT, NEW, LANES = 192, 64, 4

def main():
    cfg = reduced(get_config("longchat-7b"))   # the paper's own eval model
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (LANES, PROMPT))
    params = None
    for policy, prune in (
        ("unicaim", baselines.unicaim(heavy=56, reserve=16, select_k=24,
                                      score_bits=3, sink_tokens=2,
                                      recent_window=8)),
        ("h2o", baselines.h2o(heavy=56, reserve=16)),
        ("streaming", baselines.streaming(72, sinks=2)),
        ("dense", baselines.dense(PROMPT + NEW + 8)),
    ):
        model = Model(cfg, prune)
        if params is None:
            params = model.init(jax.random.PRNGKey(0))
        loop = ServeLoop(model, params, lanes=LANES, prompt_len=PROMPT,
                         max_new=NEW)
        t0 = time.time()
        loop.admit(prompts)
        while loop.step():
            pass
        dt = time.time() - t0
        kv_bytes = sum(x.nbytes for x in jax.tree.leaves(loop.state.kv)) \
            if loop.state.kv is not None else 0
        print(f"{policy:10s} cache={prune.slots if policy != 'dense' else PROMPT + NEW + 8:5d} slots "
              f"kv={kv_bytes/2**20:7.1f}MiB  "
              f"{LANES * NEW / dt:7.1f} tok/s  "
              f"({dt:.1f}s for {LANES}x{NEW} tokens)")

if __name__ == "__main__":
    main()
