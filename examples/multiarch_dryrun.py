"""Walk the assigned-architecture registry: instantiate every arch at a
reduced scale, run one forward + one decode step, and print family, param
counts (full config), and UniCAIM applicability — a living tour of
deliverable (f).

Run:  PYTHONPATH=src python examples/multiarch_dryrun.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import baselines
from repro.models.transformer import Model

ARCHS = [
    "whisper-base", "minitron-8b", "starcoder2-3b", "phi3-medium-14b",
    "granite-3-2b", "deepseek-v3-671b", "grok-1-314b", "zamba2-7b",
    "mamba2-1.3b", "llava-next-mistral-7b",
]

def main():
    prune = baselines.unicaim(heavy=48, reserve=16, select_k=16,
                              sink_tokens=2, recent_window=8)
    print(f"{'arch':26s} {'family':8s} {'params':>9s} {'active':>9s} "
          f"{'unicaim?':10s} fwd/decode")
    for arch in ARCHS:
        full = get_config(arch)
        cfg = reduced(full)
        model = Model(cfg, prune)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 48), 0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["enc_embed"] = jnp.zeros((2, cfg.frontend_len,
                                            cfg.d_model))
        elif cfg.frontend != "none":
            batch[f"{cfg.frontend}_embed"] = jnp.zeros(
                (2, cfg.frontend_len, cfg.d_model))
        logits, _ = jax.jit(model.train_logits)(params, batch)
        lg, state = jax.jit(model.prefill)(params, batch)
        lg2, _ = jax.jit(model.decode_step)(params, state,
                                            jnp.argmax(lg, -1))
        applic = {"ssm": "no (no KV)", "hybrid": "attn only"}.get(
            full.family, "yes")
        print(f"{arch:26s} {full.family:8s} "
              f"{full.param_count()/1e9:8.1f}B "
              f"{full.active_param_count()/1e9:8.1f}B "
              f"{applic:10s} {logits.shape} / {lg2.shape}")

if __name__ == "__main__":
    main()
